//! Pipeline parallelism: `s` stages wrapping any boxed inner tensor mesh.
//!
//! This is the second wrapper leaf (after [`crate::parallel::hybrid`]) and
//! the first that changes the *schedule* rather than the layout: the layer
//! stack splits into `s` contiguous stages, each stage group runs the
//! unchanged inner mesh on its slice, and the batch streams through as `m`
//! micro-batches. The only new communication is point-to-point: each
//! micro-batch's stage-boundary activation moves forward one hop
//! ([`PIPE_TAG`] kind 0), its gradient moves backward one hop (kind 2),
//! and the full model output / embedding gradient are relayed once per
//! step (kinds 1 and 3) so the replicated head/loss and embedding backward
//! run bit-identically on every rank.
//!
//! ## Bit-exactness
//!
//! The pipelined step is **bitwise identical** to the unpipelined run of
//! the same inner mesh on the same global batch (pinned by
//! `rust/tests/model_parity.rs`):
//!
//! * forward/backward-`dx` per micro-batch touch disjoint row ranges, and
//!   every row-wise op (GEMM rows, layernorm rows, per-sequence attention)
//!   is independent across rows — `config::validate` requires
//!   `batch % micro_batches == 0`, so micro-batches hold whole sequences;
//! * weight gradients are computed **once** per layer on the
//!   micro-batches' rows concatenated in order ([`crate::model::block_wgrad`]
//!   at the flush), not accumulated per micro-batch — per-micro-batch `dW`
//!   sums would reorder float additions.
//!
//! ## Schedule
//!
//! [`pipeline_core_step`] runs a GPipe-style flush schedule: all `m`
//! forward micro-batches, then all `m` backwards in reverse order, then
//! the weight-gradient flush. On the virtual clock this has the classic
//! bubble fraction `(s−1)/(m+s−1)` (mirrored in closed form by
//! `crate::costmodel::pipeline_bubble_fraction` and pinned bitwise against
//! the engine clock). The steady-state portion is exactly 1F1B's: with the
//! backward sweep in reverse micro-batch order, stage `k` starts its first
//! backward as soon as stage `k+1` finishes it, so no extra memory or time
//! is spent versus the 1F1B ordering at the same `m` — the stash high-water
//! mark is `m` caches per stage either way (documented trade-off table in
//! [`crate::parallel`]).

use crate::collectives::all_gather;
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::{mesh_for_pipeline_inner, ShardSpec, Stage};
use crate::model::{
    block_bwd_dx, block_wgrad, core_fwd, BlockBwdStash, BlockCache, BlockTensors, WgradActs,
};
use crate::parallel::{
    hybrid::Hybrid, oned::Ctx1D, threed::Ctx3D, twod::Ctx2D, twofived::Ctx25D, ParallelOps,
};
use crate::tensor::Tensor;
use crate::topology::{Cube, Mesh, PipelineInner};

/// Tag namespace for pipeline point-to-point traffic (disjoint from the
/// collective sequence tags and the checkpoint-donation tag).
pub const PIPE_TAG: u64 = 0xF1F0_0000_0000_0000;

/// Message kinds within [`PIPE_TAG`]: `0` forward boundary activation,
/// `1` model-output relay, `2` backward boundary gradient, `3` embedding
/// gradient relay. `u` is the micro-batch (kinds 0/2) or the receiving
/// stage (kinds 1/3).
fn tag(kind: u64, u: usize) -> u64 {
    PIPE_TAG | (kind << 32) | u as u64
}

/// Tag namespace for the serving relay (prefill/decode stage hops + output
/// fan-out) — disjoint from [`PIPE_TAG`] and the collective sequence tags.
/// Fixed tags are safe across decode steps: p2p matching is FIFO per
/// `(sender, tag)` and the serve schedule is strictly sequential per hop.
pub const SERVE_TAG: u64 = 0x5EB0_0000_0000_0000;

/// Kinds within [`SERVE_TAG`]: `0` prefill boundary hop, `1` prefill
/// output fan-out, `2` decode boundary hop, `3` decode output fan-out.
/// `u` is the receiving stage.
fn serve_tag(kind: u64, u: usize) -> u64 {
    SERVE_TAG | (kind << 32) | u as u64
}

/// `s` pipeline stages wrapping a boxed inner tensor-mesh leaf.
///
/// All math delegates to the inner leaf (built with a rank base of
/// `stage·inner_world`, the same `with_base` hook the hybrid wrapper
/// uses); the one override is [`ParallelOps::gather_activation`], which
/// gathers over the *stage group* instead of the world — the default
/// world-wide all-gather would deadlock across stages that are busy with
/// different micro-batches.
pub struct Pipeline {
    inner: Box<dyn ParallelOps>,
    stage: usize,
    stages: usize,
    micro_batches: usize,
    inner_world: usize,
    inner_rank: usize,
    spec: ShardSpec,
}

impl Pipeline {
    /// Build the leaf for `rank` of a `stages × inner(edge)` mesh.
    pub fn for_kind(
        stages: usize,
        micro_batches: usize,
        inner: PipelineInner,
        edge: usize,
        rank: usize,
    ) -> Pipeline {
        assert!(stages >= 1, "pipeline needs at least one stage");
        assert!(micro_batches >= 1, "pipeline needs at least one micro-batch");
        let iw = inner.as_parallelism().world_size(edge);
        assert!(rank < stages * iw);
        let stage = rank / iw;
        let inner_rank = rank % iw;
        let base = stage * iw;
        let inner_ops: Box<dyn ParallelOps> = match inner {
            PipelineInner::OneD => Box::new(Ctx1D::with_base(edge, inner_rank, base)),
            PipelineInner::TwoD => {
                Box::new(Ctx2D::with_base(Mesh::new(edge), inner_rank, base))
            }
            PipelineInner::ThreeD => Box::new(Ctx3D::with_dirs_base(
                Cube::new(edge),
                inner_rank,
                crate::dist::Dirs::canonical(),
                base,
            )),
            PipelineInner::TwoFiveD { depth } => {
                Box::new(Ctx25D::with_base(edge, depth, inner_rank, base))
            }
            PipelineInner::Hybrid { replicas, inner } => {
                Box::new(Hybrid::with_base(replicas, inner, edge, inner_rank, base))
            }
        };
        let spec = ShardSpec::pipeline(
            stages,
            micro_batches,
            mesh_for_pipeline_inner(inner, edge),
            rank,
        );
        Pipeline {
            inner: inner_ops,
            stage,
            stages,
            micro_batches,
            inner_world: iw,
            inner_rank,
            spec,
        }
    }

    /// This rank's stage index.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// Total pipeline stages `s`.
    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Micro-batches `m` streamed through the pipeline per step.
    pub fn micro_batches(&self) -> usize {
        self.micro_batches
    }

    /// Ranks in one stage group (the inner mesh's world).
    pub fn inner_world(&self) -> usize {
        self.inner_world
    }

    /// First global rank of this rank's stage group.
    pub fn base(&self) -> usize {
        self.stage * self.inner_world
    }

    /// Global layer indices this stage owns: the `stage`-th of `s`
    /// contiguous slices (`config::validate` requires `layers % s == 0`).
    pub fn layer_range(&self, layers: usize) -> std::ops::Range<usize> {
        assert_eq!(
            layers % self.stages,
            0,
            "layers {layers} must divide into {} pipeline stages",
            self.stages
        );
        let per = layers / self.stages;
        self.stage * per..(self.stage + 1) * per
    }
}

impl ParallelOps for Pipeline {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.matmul_nn(ep, x, w, stage)
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.matmul_nt(ep, dy, w, stage)
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, stage: Stage) -> Tensor {
        self.inner.matmul_tn(ep, x, dy, stage)
    }

    fn matmul_nn_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor) {
        self.inner.matmul_nn_backward(ep, dy, x, w, stage)
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        self.inner.linear_fwd(ep, x, w, b, stage)
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        self.inner.linear_bwd(ep, dy, x, w, stage)
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        self.inner.vec_op(ep, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        self.inner.layernorm(ep, x, gamma, beta, eps, hidden)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        self.inner.layernorm_backward(ep, dy, xhat, inv_std, gamma, hidden)
    }

    fn linear_bwd_dx(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.linear_bwd_dx(ep, dy, w, stage)
    }

    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        self.inner.linear_bwd_dw(ep, dy, x, stage)
    }

    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> Tensor {
        self.inner.layernorm_backward_dx(ep, dy, xhat, inv_std, gamma, hidden)
    }

    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        self.inner.layernorm_param_grads(ep, dy, xhat)
    }

    /// Gather over the **stage group** (`base..base+iw`), not the world:
    /// other stage groups are running different micro-batches, so the
    /// default world-wide all-gather would deadlock. Assembly uses the
    /// inner spec — stage groups are layout-identical activation replicas.
    fn gather_activation(
        &self,
        ep: &mut Endpoint,
        local: &Tensor,
        rows: usize,
        cols: usize,
    ) -> Tensor {
        let ispec = self.inner.spec();
        if !ispec.shards_activation() {
            return local.clone();
        }
        let group: Vec<usize> = (self.base()..self.base() + self.inner_world).collect();
        let parts = all_gather(ep, &group, local);
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut out = ep.pooled_tensor(&[rows, cols]);
        ispec.assemble_activation_into(&parts, rows, cols, &mut out);
        out
    }

    /// Serving relay: the whole slot batch moves through the stage chain
    /// in one hop per stage — no micro-batching (a decode step is one
    /// token per slot; slicing it would only add latency). The last stage
    /// fans its output back to every stage so all ranks return the
    /// block-stack output in inner-entry layout, keeping the
    /// autoregressive feedback loop rank-local.
    fn serve_prefill(
        &self,
        ep: &mut Endpoint,
        blocks: &[BlockTensors],
        x: &Tensor,
        cfg: &ModelConfig,
        lens: &[usize],
        kv: &mut [crate::model::attention::DecodeKv],
    ) -> Tensor {
        self.serve_relay(ep, blocks, x, cfg, Some(lens), kv, 0)
    }

    fn serve_decode(
        &self,
        ep: &mut Endpoint,
        blocks: &[BlockTensors],
        x: &Tensor,
        cfg: &ModelConfig,
        kv: &mut [crate::model::attention::DecodeKv],
    ) -> Tensor {
        self.serve_relay(ep, blocks, x, cfg, None, kv, 2)
    }
}

impl Pipeline {
    /// Shared stage-relay schedule for [`ParallelOps::serve_prefill`]
    /// (`kind = 0`, `lens = Some`) and [`ParallelOps::serve_decode`]
    /// (`kind = 2`, `lens = None`).
    fn serve_relay(
        &self,
        ep: &mut Endpoint,
        blocks: &[BlockTensors],
        x: &Tensor,
        cfg: &ModelConfig,
        lens: Option<&[usize]>,
        kv: &mut [crate::model::attention::DecodeKv],
        kind: u64,
    ) -> Tensor {
        assert_eq!(blocks.len(), kv.len());
        let (s, iw, ir, stage) = (self.stages, self.inner_world, self.inner_rank, self.stage);
        let mut h = if stage == 0 {
            x.clone()
        } else {
            ep.recv((stage - 1) * iw + ir, serve_tag(kind, stage))
        };
        for (p, kvl) in blocks.iter().zip(kv.iter_mut()) {
            h = match lens {
                Some(lens) => {
                    crate::model::block::prefill_block_fwd(ep, self, p, &h, cfg, kvl, lens)
                }
                None => crate::model::block::decode_block_fwd(ep, self, p, &h, cfg, kvl),
            };
        }
        if stage + 1 < s {
            ep.send_owned((stage + 1) * iw + ir, serve_tag(kind, stage + 1), h);
            ep.recv((s - 1) * iw + ir, serve_tag(kind + 1, stage))
        } else {
            for k in 0..s - 1 {
                ep.send(k * iw + ir, serve_tag(kind + 1, k), &h);
            }
            h
        }
    }
}

/// Everything one pipelined core step produces on this rank.
pub struct PipelineOutput {
    /// Full model output `(batch·seq, hidden)` — identical on all ranks.
    pub y_full: Tensor,
    /// Full embedding gradient — identical on all ranks.
    pub dx_full: Tensor,
    /// Per-local-layer weight gradients (forward layer order, this
    /// stage's slice only).
    pub grads: Vec<BlockTensors>,
    /// Virtual clock right after `y_full` is available on this rank —
    /// the forward/backward split point for per-phase timing.
    pub fwd_done_clock: f64,
}

/// Phantom-aware contiguous row slice `[r0, r0+rows)` of a 2-D tensor.
fn row_slice(t: &Tensor, r0: usize, rows: usize) -> Tensor {
    let cols = t.dims2().1;
    if t.is_phantom() {
        return Tensor::phantom(&[rows, cols]);
    }
    t.block(r0, 0, rows, cols).compact()
}

/// The weight-gradient flush: one [`block_wgrad`] per local layer (reverse
/// layer order, mirroring the joint backward) over the micro-batches' rows
/// concatenated in order. Consumes the stashes.
fn wgrad_flush(
    ep: &mut Endpoint,
    ops: &Pipeline,
    blocks: &[BlockTensors],
    stashes: &mut [Vec<Option<BlockBwdStash>>],
    caches: &[Vec<BlockCache>],
) -> Vec<BlockTensors> {
    let mut grads: Vec<Option<BlockTensors>> = (0..blocks.len()).map(|_| None).collect();
    for l in (0..blocks.len()).rev() {
        let layer: Vec<BlockBwdStash> = stashes[l]
            .iter_mut()
            .map(|s| s.take().expect("every micro-batch must have stashed layer grads"))
            .collect();
        let stash = BlockBwdStash::concat(&layer);
        let cache_refs: Vec<&BlockCache> = caches.iter().map(|mb| &mb[l]).collect();
        let acts = WgradActs::concat(&cache_refs);
        grads[l] = Some(block_wgrad(ep, ops, &stash, &acts));
        ep.drain_ready();
    }
    grads.into_iter().map(|g| g.expect("flushed every layer")).collect()
}

/// One pipelined forward + backward over this stage's `blocks` (the
/// stage's contiguous slice of the layer stack, already sharded by the
/// inner mesh).
///
/// `x_global` is the full embedding output `(batch·seq, hidden)` — every
/// rank holds it (the embedding, like the head, is replicated and outside
/// the parallelized region). `head` maps the full model output to the full
/// loss gradient; it runs on **every** rank with the bit-identical
/// `y_full`, so its outputs (and any losses it records) agree across
/// ranks without further communication.
///
/// Returns the full output, full embedding gradient, and this stage's
/// weight gradients. Deferred collectives issued by the inner mesh (hybrid
/// replica syncs) may still be in flight — the caller joins at the
/// optimizer boundary, same as the unpipelined path.
pub fn pipeline_core_step(
    ep: &mut Endpoint,
    ops: &Pipeline,
    blocks: &[BlockTensors],
    x_global: &Tensor,
    cfg: &ModelConfig,
    head: &mut dyn FnMut(&mut Endpoint, &Tensor) -> Tensor,
) -> PipelineOutput {
    let s = ops.stages;
    let m = ops.micro_batches;
    let stage = ops.stage;
    let iw = ops.inner_world;
    let ir = ops.inner_rank;
    let (rows, cols) = x_global.dims2();
    assert_eq!(rows % m, 0, "activation rows must divide into micro-batches");
    let mb_rows = rows / m;
    let next_peer = (stage + 1) * iw + ir; // valid when stage + 1 < s
    let prev_peer = if stage > 0 { (stage - 1) * iw + ir } else { usize::MAX };

    // --- forward: stream micro-batches through the stage chain --------
    let mut caches: Vec<Vec<BlockCache>> = Vec::with_capacity(m);
    let mut y_parts: Vec<Tensor> = Vec::with_capacity(m);
    for u in 0..m {
        let x_loc = if stage == 0 {
            let xu = row_slice(x_global, u * mb_rows, mb_rows);
            ops.scatter_activation(ep, &xu)
        } else {
            ep.recv(prev_peer, tag(0, u))
        };
        let (y_loc, cache) = core_fwd(ep, ops, blocks, &x_loc, cfg);
        caches.push(cache);
        if stage + 1 < s {
            ep.send_owned(next_peer, tag(0, u), y_loc);
        } else {
            y_parts.push(ops.gather_activation(ep, &y_loc, mb_rows, cols));
        }
    }

    // --- output relay: the last stage owns the only full y ------------
    let y_full = if stage + 1 == s {
        let y = Tensor::concat_rows(&y_parts);
        for k in 0..s - 1 {
            ep.send(k * iw + ir, tag(1, k), &y);
        }
        y
    } else {
        ep.recv((s - 1) * iw + ir, tag(1, stage))
    };
    let fwd_done_clock = ep.clock;

    // Head/loss on the full output — replicated, bit-identical per rank.
    let dy_full = head(ep, &y_full);

    // --- backward: reverse micro-batch order, dx chains backward ------
    let mut dx_parts: Vec<Option<Tensor>> = (0..m).map(|_| None).collect();
    let mut stashes: Vec<Vec<Option<BlockBwdStash>>> =
        blocks.iter().map(|_| (0..m).map(|_| None).collect()).collect();
    for u in (0..m).rev() {
        let mut cur = if stage + 1 == s {
            let dyu = row_slice(&dy_full, u * mb_rows, mb_rows);
            ops.scatter_activation(ep, &dyu)
        } else {
            ep.recv(next_peer, tag(2, u))
        };
        for l in (0..blocks.len()).rev() {
            let (dx, stash) = block_bwd_dx(ep, ops, &blocks[l], &caches[u][l], &cur, cfg);
            stashes[l][u] = Some(stash);
            cur = dx;
            ep.drain_ready();
        }
        if stage > 0 {
            ep.send_owned(prev_peer, tag(2, u), cur);
        } else {
            dx_parts[u] = Some(ops.gather_activation(ep, &cur, mb_rows, cols));
        }
    }

    // --- embedding-gradient relay + weight-gradient flush -------------
    // Stage 0 sends the relay first so later stages can overlap their
    // flush with the transfer; sends never block, so ordering is free.
    let (dx_full, grads) = if stage == 0 {
        let parts: Vec<Tensor> = dx_parts
            .into_iter()
            .map(|p| p.expect("stage 0 gathered every micro-batch"))
            .collect();
        let dxf = Tensor::concat_rows(&parts);
        for k in 1..s {
            ep.send(k * iw + ir, tag(3, k), &dxf);
        }
        let grads = wgrad_flush(ep, ops, blocks, &mut stashes, &caches);
        (dxf, grads)
    } else {
        let grads = wgrad_flush(ep, ops, blocks, &mut stashes, &caches);
        let dxf = ep.recv(ir, tag(3, stage));
        (dxf, grads)
    };

    PipelineOutput { y_full, dx_full, grads, fwd_done_clock }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::model::{block_bwd, core_bwd, init_dense_blocks, ParEnv};
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;
    use crate::topology::Parallelism;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    fn assert_grads_eq(a: &BlockTensors, b: &BlockTensors, what: &str) {
        assert_eq!(a.w_qkv, b.w_qkv, "{what} w_qkv");
        assert_eq!(a.b_qkv, b.b_qkv, "{what} b_qkv");
        assert_eq!(a.w_proj, b.w_proj, "{what} w_proj");
        assert_eq!(a.b_proj, b.b_proj, "{what} b_proj");
        assert_eq!(a.w_fc1, b.w_fc1, "{what} w_fc1");
        assert_eq!(a.b_fc1, b.b_fc1, "{what} b_fc1");
        assert_eq!(a.w_fc2, b.w_fc2, "{what} w_fc2");
        assert_eq!(a.b_fc2, b.b_fc2, "{what} b_fc2");
        assert_eq!(a.ln1_g, b.ln1_g, "{what} ln1_g");
        assert_eq!(a.ln1_b, b.ln1_b, "{what} ln1_b");
        assert_eq!(a.ln2_g, b.ln2_g, "{what} ln2_g");
        assert_eq!(a.ln2_b, b.ln2_b, "{what} ln2_b");
    }

    /// Reference run: the same inner mesh, unpipelined, full batch.
    fn reference_oned(
        edge: usize,
        cfg: &ModelConfig,
        x: &Tensor,
    ) -> Vec<(Tensor, Tensor, Vec<BlockTensors>)> {
        let dense = init_dense_blocks(cfg, 42);
        let (cfg2, x2) = (cfg.clone(), x.clone());
        run_spmd(edge, NetModel::zero(), move |rank, ep| {
            let env = ParEnv::new(Parallelism::OneD, edge, rank);
            let ops = env.ops();
            let blocks: Vec<BlockTensors> = dense.iter().map(|d| ops.shard_block(d)).collect();
            let (rows, cols) = x2.dims2();
            let x_loc = ops.scatter_activation(ep, &x2);
            let (y_loc, caches) = crate::model::core_fwd(ep, ops, &blocks, &x_loc, &cfg2);
            let y_full = ops.gather_activation(ep, &y_loc, rows, cols);
            let dy_full = y_full.scale(0.5);
            let dy_loc = ops.scatter_activation(ep, &dy_full);
            let (dx_loc, grads) = core_bwd(ep, ops, &blocks, &caches, &dy_loc, &cfg2);
            let dx_full = ops.gather_activation(ep, &dx_loc, rows, cols);
            ep.join_all();
            (y_full, dx_full, grads)
        })
    }

    /// Pipelined run over the same inner mesh and global batch.
    fn pipelined_oned(
        stages: usize,
        micro_batches: usize,
        edge: usize,
        cfg: &ModelConfig,
        x: &Tensor,
    ) -> Vec<(usize, Tensor, Tensor, Vec<BlockTensors>)> {
        let dense = init_dense_blocks(cfg, 42);
        let world = stages * edge;
        let (cfg2, x2) = (cfg.clone(), x.clone());
        run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ops = Pipeline::for_kind(stages, micro_batches, PipelineInner::OneD, edge, rank);
            let range = ops.layer_range(cfg2.layers);
            let blocks: Vec<BlockTensors> =
                dense[range.clone()].iter().map(|d| ops.shard_block(d)).collect();
            let out = pipeline_core_step(
                ep,
                &ops,
                &blocks,
                &x2,
                &cfg2,
                &mut |_ep, y| y.scale(0.5),
            );
            ep.join_all();
            (range.start, out.y_full, out.dx_full, out.grads)
        })
    }

    #[test]
    fn pipeline_matches_unpipelined_inner_bitwise() {
        // Pipeline(2 stages, 2 micro-batches, 1-D p=2) at world 4 must be
        // bitwise identical — output, embedding gradient, and every weight
        // gradient — to the unpipelined 1-D p=2 run on the same global
        // batch. This is the leaf's headline invariant.
        let cfg = ModelConfig::tiny(); // layers=2, batch=4
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 7);
        let reference = reference_oned(2, &cfg, &x);
        let pipelined = pipelined_oned(2, 2, 2, &cfg, &x);
        for (rank, (layer0, y, dx, grads)) in pipelined.iter().enumerate() {
            let inner_rank = rank % 2;
            let (ref_y, ref_dx, ref_grads) = &reference[inner_rank];
            assert_eq!(y, ref_y, "rank {rank} y_full");
            assert_eq!(dx, ref_dx, "rank {rank} dx_full");
            for (l, g) in grads.iter().enumerate() {
                assert_grads_eq(g, &ref_grads[layer0 + l], &format!("rank {rank} layer"));
            }
        }
    }

    #[test]
    fn micro_batch_count_does_not_change_results() {
        // m=1 (no micro-batching) and m=4 slice the same rows differently
        // but must produce bitwise identical outputs and gradients.
        let cfg = ModelConfig::tiny();
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 8);
        let m1 = pipelined_oned(2, 1, 2, &cfg, &x);
        let m4 = pipelined_oned(2, 4, 2, &cfg, &x);
        for (a, b) in m1.iter().zip(m4.iter()) {
            assert_eq!(a.1, b.1, "y_full");
            assert_eq!(a.2, b.2, "dx_full");
            for (ga, gb) in a.3.iter().zip(b.3.iter()) {
                assert_grads_eq(ga, gb, "m1 vs m4");
            }
        }
    }

    #[test]
    fn single_stage_pipeline_matches_joint_backward() {
        // s=1 degenerates to micro-batched execution without any p2p; it
        // must still match the joint (block_bwd) full-batch run bitwise.
        let cfg = ModelConfig::tiny();
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 9);
        let dense = init_dense_blocks(&cfg, 42);
        let (cfg2, x2, dense2) = (cfg.clone(), x.clone(), dense.clone());
        let joint = run_spmd(1, NetModel::zero(), move |_, ep| {
            let env = ParEnv::seq();
            let ops = env.ops();
            let blocks: Vec<BlockTensors> =
                dense2.iter().map(|d| ops.shard_block(d)).collect();
            let (y, caches) = crate::model::core_fwd(ep, ops, &blocks, &x2, &cfg2);
            let dy = y.scale(0.5);
            let mut grads = Vec::new();
            let mut cur = dy;
            for (p, c) in blocks.iter().zip(caches.iter()).rev() {
                let (dx, g) = block_bwd(ep, ops, p, c, &cur, &cfg2);
                grads.push(g);
                cur = dx;
            }
            grads.reverse();
            (y, cur, grads)
        })
        .pop()
        .unwrap();
        let piped = pipelined_oned(1, 2, 1, &cfg, &x).pop().unwrap();
        assert_eq!(piped.1, joint.0, "y_full");
        assert_eq!(piped.2, joint.1, "dx_full");
        for (g, gr) in piped.3.iter().zip(joint.2.iter()) {
            assert_grads_eq(g, gr, "s=1");
        }
    }

    #[test]
    fn stage_geometry_and_layer_ranges() {
        let p = Pipeline::for_kind(4, 8, PipelineInner::OneD, 2, 5);
        assert_eq!(p.stage(), 2);
        assert_eq!(p.base(), 4);
        assert_eq!(p.inner_world(), 2);
        assert_eq!(p.layer_range(8), 4..6);
        assert_eq!(p.kind().world_size(2), 8);
        let ph = Pipeline::for_kind(
            2,
            4,
            PipelineInner::Hybrid {
                replicas: 2,
                inner: crate::topology::HybridInner::OneD,
            },
            2,
            6,
        );
        assert_eq!(ph.stage(), 1);
        assert_eq!(ph.inner_world(), 4);
    }
}
