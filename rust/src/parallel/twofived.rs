//! 2.5-D tensor parallelism — Tesseract-style depth-stacked SUMMA.
//!
//! The `p × p × d` mesh holds `d` depth layers, each a SUMMA `p × p` grid
//! (see [`crate::dist::MeshSpec::Tess`] for the layout and the
//! memory/communication trade-off table). The decomposition is exactly
//! 1-D Megatron **along the depth axis** composed with 2-D SUMMA **within
//! each layer**:
//!
//! * the `Expand` weight is column-slabbed across depth (each layer owns
//!   `1/d` of the output columns — no forward depth communication, the
//!   hidden activation comes out depth-slabbed);
//! * the `Reduce` weight is row-slabbed across depth (each layer consumes
//!   its slab of the hidden activation and contributes a partial product);
//!   one **depth all-reduce** sums the partials, returning the activation
//!   to its entry layout — the all-reduce that closes each residual branch
//!   forward. Backward mirrors it: the `Expand` input gradient is the
//!   depth all-reduce of per-layer partials.
//!
//! Within a layer every matmul is the 2-D module's SUMMA on the slab
//! shapes, over grids embedded at rank base `layer · p²` — the same code
//! path as the stand-alone 2-D leaf, so the two cannot drift. Entry-layout
//! activations are replicated across depth, so layernorm and `vec_op` are
//! purely per-layer (identical results on every layer by construction).
//!
//! Exact per-rank communication volume is mirrored in closed form by
//! `crate::costmodel::mm25d_fwd_bytes_per_rank` and pinned against the
//! engine ledger by the costmodel tests.
//!
//! **Overlap.** The depth all-reduces look like data-parallel grad syncs
//! but are *activation* sums: the residual branch (forward) and the
//! `Expand` input gradient (backward) are consumed by the immediately
//! following op, so they stay blocking — deferring them would only move
//! the stall to the next instruction. Like the other tensor meshes, this
//! leaf's clock is `CUBIC_OVERLAP`-invariant; the hideable boundary is the
//! hybrid wrapper's replica grad sync.

use crate::collectives::all_reduce;
use crate::comm::Endpoint;
use crate::dist::{ShardSpec, Stage};
use crate::parallel::twod::{self, bcast_bias, summa_nn, summa_nt, summa_tn, Ctx2D};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;
use crate::topology::Mesh;

/// Per-rank context on the `p × p × d` Tesseract mesh.
pub struct Ctx25D {
    /// This rank's grid, embedded at global base `base + layer · p²`.
    grid: Ctx2D,
    layer: usize,
    depth: usize,
    grid_rank: usize,
    /// Global rank of `(layer 0, grid rank 0)` — non-zero when a hybrid
    /// replica group embeds this mesh.
    base: usize,
    spec: ShardSpec,
}

impl Ctx25D {
    /// Context for `rank` of a stand-alone `p²·depth` Tesseract (base 0).
    pub fn new(p: usize, depth: usize, rank: usize) -> Self {
        Self::with_base(p, depth, rank, 0)
    }

    /// Like [`Ctx25D::new`] but the mesh occupies global ranks
    /// `base..base + p²·depth`. `rank` is mesh-local; the endpoint's global
    /// rank must be `base + rank`.
    pub fn with_base(p: usize, depth: usize, rank: usize, base: usize) -> Self {
        assert!(depth >= 1, "2.5-D mesh needs depth >= 1");
        let mesh = Mesh::new(p);
        assert!(rank < mesh.size() * depth);
        let layer = rank / mesh.size();
        let grid_rank = rank % mesh.size();
        let grid = Ctx2D::with_base(mesh, grid_rank, base + layer * p * p);
        let spec = ShardSpec::twofived(p, depth, rank);
        Ctx25D { grid, layer, depth, grid_rank, base, spec }
    }

    /// The SUMMA grid edge `p`.
    pub fn p(&self) -> usize {
        self.grid.q()
    }

    /// Stacked grid layers `d`.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// This rank's depth layer (also its position in the depth group).
    pub fn layer(&self) -> usize {
        self.layer
    }

    /// The global ranks holding this rank's grid position on every depth
    /// layer, ordered by layer — the group of the residual-branch
    /// all-reduce. This rank sits at position `layer`.
    fn depth_group(&self) -> Vec<usize> {
        let p2 = self.p() * self.p();
        (0..self.depth).map(|l| self.base + l * p2 + self.grid_rank).collect()
    }
}

impl ParallelOps for Ctx25D {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        match stage {
            // Column-slabbed weight: the layer's SUMMA yields its slab of
            // the output — no depth communication (Megatron column form).
            Stage::Expand => summa_nn(ep, &self.grid, x, w),
            // Row-slabbed weight: per-layer partials sum over depth
            // (Megatron row form — the branch-closing all-reduce).
            Stage::Reduce => {
                let partial = summa_nn(ep, &self.grid, x, w);
                all_reduce(ep, &self.depth_group(), &partial)
            }
        }
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        match stage {
            // dX of a column-slabbed linear: per-layer partials of the full
            // input gradient sum over depth (the backward all-reduce).
            Stage::Expand => {
                let partial = summa_nt(ep, &self.grid, dy, w);
                all_reduce(ep, &self.depth_group(), &partial)
            }
            // dX of a row-slabbed linear: dY is depth-replicated; the
            // layer's SUMMA yields its slab of dX directly.
            Stage::Reduce => summa_nt(ep, &self.grid, dy, w),
        }
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, _stage: Stage) -> Tensor {
        // Both weight-gradient forms are depth-local: the slabbed operand
        // pair always lines up (Expand: replicated X × the layer's dY slab;
        // Reduce: the layer's X slab × replicated dY), yielding the layer's
        // weight-slab gradient from its own SUMMA.
        summa_tn(ep, &self.grid, x, dy)
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        match stage {
            // 2-D linear within the layer; the bias slab chunk lives on the
            // layer's grid row 0 like every Optimus vector.
            Stage::Expand => twod::linear_fwd(ep, &self.grid, x, w, b, true),
            Stage::Reduce => {
                let y = self.matmul_nn(ep, x, w, stage);
                let bias = bcast_bias(ep, &self.grid, b);
                ep.charge_memop(y.nominal_bytes() as f64);
                y.add_row_vector(&bias)
            }
        }
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        let (dx, dw, db) = twod::linear_bwd(ep, &self.grid, dy, x, w);
        match stage {
            Stage::Expand => (all_reduce(ep, &self.depth_group(), &dx), dw, db),
            Stage::Reduce => (dx, dw, db),
        }
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        twod::vec_op(ep, &self.grid, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        twod::layernorm(ep, &self.grid, x, gamma, beta, eps, hidden)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        twod::layernorm_backward(ep, &self.grid, dy, xhat, inv_std, gamma, hidden)
    }

    // Split backward halves (micro-batch pipelining): both weight-gradient
    // forms are depth-local (see `matmul_tn`), so everything delegates to
    // the layer's grid — the same 2-D code path as the stand-alone leaf.

    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        _stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        twod::linear_bwd_dw(ep, &self.grid, dy, x)
    }

    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> Tensor {
        twod::layernorm_backward_dx(ep, &self.grid, dy, xhat, inv_std, gamma, hidden)
    }

    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        twod::layernorm_param_grads(ep, &self.grid, dy, xhat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::{DistTensor, VecRole};
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn expand_then_reduce_matmul_matches_dense() {
        // A residual branch's two linears: Expand (depth-column-slabbed)
        // then Reduce (depth-row-slabbed) must return the entry layout with
        // the dense product, closing with one depth all-reduce.
        let (p, d) = (2usize, 2usize);
        let world = p * p * d;
        let (m, n, k) = (8usize, 16usize, 32usize);
        let x = randt(&[m, n], 1);
        let w1 = randt(&[n, k], 2);
        let w2 = randt(&[k, n], 3);
        let y_ref = x.matmul(&w1).matmul(&w2);
        let (x2, w1c, w2c) = (x.clone(), w1.clone(), w2.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx25D::new(p, d, rank);
            let xl = ctx.spec().shard_activation(&x2);
            let w1s = ctx.spec().shard_weight(Stage::Expand, &w1c);
            let w2s = ctx.spec().shard_weight(Stage::Reduce, &w2c);
            let h = ctx.matmul_nn(ep, &xl, &w1s, Stage::Expand);
            ctx.matmul_nn(ep, &h, &w2s, Stage::Reduce)
        });
        let parts: Vec<DistTensor> = out
            .into_iter()
            .enumerate()
            .map(|(r, t)| DistTensor::from_local(&ShardSpec::twofived(p, d, r), t))
            .collect();
        let y = DistTensor::assemble_activation(&parts, m, n);
        assert!(y.max_abs_diff(&y_ref) < 1e-3, "{}", y.max_abs_diff(&y_ref));
    }

    #[test]
    fn depth_one_degenerates_to_two_d() {
        // d = 1 must be bit-compatible with the plain 2-D leaf: same
        // shards, same SUMMA, and the depth all-reduce a no-op.
        let p = 2usize;
        let (m, n, k) = (8usize, 8usize, 8usize);
        let x = randt(&[m, n], 4);
        let w = randt(&[n, k], 5);
        let (x2, wc) = (x.clone(), w.clone());
        let tess = run_spmd(p * p, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx25D::new(p, 1, rank);
            let xl = ctx.spec().shard_activation(&x2);
            let ws = ctx.spec().shard_weight(Stage::Reduce, &wc);
            ctx.matmul_nn(ep, &xl, &ws, Stage::Reduce)
        });
        let (x3, wc2) = (x.clone(), w.clone());
        let twod_out = run_spmd(p * p, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(p), rank);
            let xl = ctx.spec().shard_activation(&x3);
            let ws = ctx.spec().shard_weight(Stage::Reduce, &wc2);
            ctx.matmul_nn(ep, &xl, &ws, Stage::Reduce)
        });
        for (rank, (a, b)) in tess.iter().zip(twod_out.iter()).enumerate() {
            assert_eq!(a, b, "rank {rank}: d=1 must equal the 2-D leaf bitwise");
        }
    }

    #[test]
    fn vec_op_matches_dense_on_every_layer() {
        let (p, d) = (2usize, 2usize);
        let world = p * p * d;
        let (m, n) = (8usize, 16usize);
        let a = randt(&[m, n], 6);
        let v = randt(&[n], 7);
        let want = a.add_row_vector(&v);
        let (a2, v2) = (a.clone(), v.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx25D::new(p, d, rank);
            let al = ctx.spec().shard_activation(&a2);
            let chunk = ctx.spec().shard_vector(VecRole::Norm, &v2);
            ctx.vec_op(ep, &al, chunk.as_ref(), false)
        });
        // Every depth layer computes the same grid-blocked result: gather
        // each layer's p² blocks through the plain 2-D layout.
        let mesh = Mesh::new(p);
        for layer in 0..d {
            let parts = &out[layer * p * p..(layer + 1) * p * p];
            let got = crate::dist::Layout2D::gather(&mesh, parts, m, n);
            assert!(got.max_abs_diff(&want) < 1e-5, "layer {layer}");
        }
    }

    #[test]
    fn phantom_mode_charges_time_and_depth_allreduce_bytes() {
        let (p, d) = (2usize, 2usize);
        let world = p * p * d;
        let out = run_spmd(world, NetModel::longhorn_v100(), move |rank, ep| {
            let ctx = Ctx25D::new(p, d, rank);
            // Reduce-stage shapes: x slab blocks (M/p, N/(d·p)), w slab
            // blocks (N/(d·p), K/p).
            let x = Tensor::phantom(&[64, 32]);
            let w = Tensor::phantom(&[32, 64]);
            let y = ctx.matmul_nn(ep, &x, &w, Stage::Reduce);
            (y.is_phantom(), y.shape().to_vec(), ep.clock, ep.stats.bytes_sent)
        });
        for (ph, shape, clock, bytes) in out {
            assert!(ph);
            assert_eq!(shape, vec![64, 64]);
            assert!(clock > 0.0, "virtual time must advance in phantom mode");
            assert!(bytes > 0, "SUMMA + depth all-reduce must move bytes");
        }
    }
}
