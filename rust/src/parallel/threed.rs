//! The paper's contribution: load-balanced 3-D parallel matrix operations
//! (§3.1, Algorithms 1–8).
//!
//! Every function here is SPMD: it runs on each rank of the `p³` cube with
//! that rank's shard, communicates along axis-aligned lines via
//! [`crate::collectives`], and returns that rank's shard of the result.
//!
//! ## Structure
//!
//! All six matmul algorithms decompose into the same three moves:
//!
//! 1. **gather-merge** each operand along its direction: an all-gather over
//!    the `p`-rank line, concatenating shards along whichever dimension of
//!    the operand's [`Layout3D`] is (inner-)split by that axis;
//! 2. a **local matmul** of form NN / NT / TN on the merged `(·/p, ·/p)`
//!    blocks, charged to the virtual clock at `2·m·n·k` flops;
//! 3. **reduce-scatter-split** of the partial product along the output
//!    direction, splitting rows or columns so the result lands exactly in
//!    the output's `Layout3D`.
//!
//! The correctness of each composition is pinned shard-for-shard against a
//! dense reference in `rust/tests/dist_matmul.rs`.
//!
//! **Overlap.** Every collective here feeds the move that follows it —
//! gather-merge produces the local matmul's operands, reduce-scatter-split
//! produces the shard the next algorithm reads — and the weight-grad
//! outputs land already in their owner's layout, so nothing in this leaf
//! is deferrable and its clock is `CUBIC_OVERLAP`-invariant. Deferred
//! collectives enter only via the hybrid wrapper's replica grad syncs
//! around the cube.

use crate::collectives::{all_gather, broadcast, reduce, reduce_scatter};
use crate::comm::Endpoint;
use crate::dist::{DiagVec3D, Dirs, Layout3D, ShardSpec, Split, Stage};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;
use crate::topology::{Coord, Cube};

/// Per-rank context for 3-D operations: the cube geometry, this rank's
/// coordinate, and the block-entry direction triple `d0` the trait
/// implementation stages its layers under. Construct once per worker with
/// [`Ctx3D::new`] (canonical `d0`) or [`Ctx3D::with_dirs`]. The free
/// functions below take explicit `dirs` and ignore `d0` — they are the
/// paper's raw Algorithms 1–8; `d0` only anchors the [`ParallelOps`] view.
pub struct Ctx3D {
    /// The `p³` cube geometry.
    pub cube: Cube,
    /// This rank's cube coordinate.
    pub coord: Coord,
    /// The block-entry direction triple layers are staged under.
    pub d0: Dirs,
    base: usize,
    spec: ShardSpec,
}

impl Ctx3D {
    /// Context for `rank` under the canonical direction triple (base 0).
    pub fn new(cube: Cube, rank: usize) -> Self {
        Self::with_dirs(cube, rank, Dirs::canonical())
    }

    /// Context for `rank` under an explicit direction triple (base 0).
    pub fn with_dirs(cube: Cube, rank: usize, d0: Dirs) -> Self {
        Self::with_dirs_base(cube, rank, d0, 0)
    }

    /// Like [`Ctx3D::with_dirs`] but the cube occupies global ranks
    /// `base..base + p³` — the hook that lets a hybrid replica group embed
    /// cubes anywhere in the rank space. `rank` is cube-local; the
    /// endpoint's global rank must be `base + rank`.
    pub fn with_dirs_base(cube: Cube, rank: usize, d0: Dirs, base: usize) -> Self {
        d0.assert_distinct();
        let coord = cube.coord_of(rank);
        let spec = ShardSpec::threed_with_dirs(cube.edge(), rank, d0);
        Ctx3D { cube, coord, d0, base, spec }
    }

    /// The cube edge `p`.
    pub fn p(&self) -> usize {
        self.cube.edge()
    }

    /// The global ranks of the axis-aligned line through this rank's
    /// coordinate (the cube's line offset by `base`). All collectives in
    /// this module go through here so embedded cubes talk to the right
    /// endpoints.
    fn line(&self, axis: crate::topology::Axis) -> Vec<usize> {
        self.cube
            .line(self.coord, axis)
            .into_iter()
            .map(|r| r + self.base)
            .collect()
    }

    /// The direction triple a `stage` linear runs under: `Expand` uses the
    /// block-entry `d0`, `Reduce` the swapped triple — so two chained
    /// linears return the activation to its entry layout (§3.2). Delegates
    /// to [`ShardSpec::stage_dirs`] so the layout and ops sides share one
    /// Stage→Dirs mapping.
    pub fn stage_dirs(&self, stage: Stage) -> Dirs {
        self.spec.stage_dirs(stage).expect("cube spec always has dirs")
    }
}

/// Additional operand layouts used by the `ABᵀ` and `AᵀB` forms. (The
/// `input`/`weight`/`output` layouts live in [`crate::dist`]; these two are
/// only ever operands of the transposed forms, so they live with them.)
pub trait Layout3DExt {
    /// Layout of the second operand of `C = A·Bᵀ` (the paper's `B_{jli}`):
    /// global shape `(K, N)`, rows split `p²` by `(dA outer, dB inner)`,
    /// cols split `p` by `dC`.
    fn nt_rhs(dirs: Dirs) -> Layout3D;
    /// Layout of the first operand of `C = Aᵀ·B` (the paper's `A_{ilj}` in
    /// Algorithm 5): global shape `(N, M)`, rows split `p` by `dC`, cols
    /// split `p²` by `(dB outer, dA inner)`.
    fn tn_lhs(dirs: Dirs) -> Layout3D;
}

impl Layout3DExt for Layout3D {
    fn nt_rhs(dirs: Dirs) -> Layout3D {
        Layout3D { row: Split::Two(dirs.a, dirs.b), col: Split::One(dirs.c) }
    }

    fn tn_lhs(dirs: Dirs) -> Layout3D {
        Layout3D { row: Split::One(dirs.c), col: Split::Two(dirs.b, dirs.a) }
    }
}

/// All-gather `shard` along `axis` and merge the parts along whichever
/// dimension of `layout` is split by `axis`. Returns the merged block
/// (one gather step of Algorithms 1/3/5).
pub fn gather_merge(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    shard: &Tensor,
    layout: Layout3D,
    axis: crate::topology::Axis,
) -> Tensor {
    let group = ctx.line(axis);
    let parts = all_gather(ep, &group, shard);
    merge_parts(parts, layout, axis)
}

fn merge_parts(parts: Vec<Tensor>, layout: Layout3D, axis: crate::topology::Axis) -> Tensor {
    let row_hit = matches!(layout.row, Split::Two(_, inner) if inner == axis)
        || matches!(layout.row, Split::One(ax) if ax == axis);
    let col_hit = matches!(layout.col, Split::Two(_, inner) if inner == axis)
        || matches!(layout.col, Split::One(ax) if ax == axis);
    match (row_hit, col_hit) {
        (true, false) => Tensor::concat_rows(&parts),
        (false, true) => Tensor::concat_cols(&parts),
        _ => panic!("layout {layout:?} is not (inner-)split along {axis:?}"),
    }
}

/// Reduce-scatter the partial product `partial` along `axis`, splitting rows
/// (`split_rows = true`) or columns so each line member keeps its chunk
/// (one reduce step of Algorithms 1/3/5). Row chunking is zero-copy: the
/// chunks are views of `partial`'s buffer (column chunks are strided and
/// extracted with one copy).
pub fn reduce_scatter_split(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    partial: Tensor,
    axis: crate::topology::Axis,
    split_rows: bool,
) -> Tensor {
    let group = ctx.line(axis);
    let chunks = if split_rows {
        partial.split_rows(ctx.p())
    } else {
        partial.split_cols(ctx.p())
    };
    reduce_scatter(ep, &group, chunks)
}

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

// ---------------------------------------------------------------------
// Algorithm 1 & 2 — C = A·B
// ---------------------------------------------------------------------

/// **Algorithm 1** (forward `C = AB`): `a` in `Layout3D::input(dirs)`
/// (global `(M, N)`), `b` in `Layout3D::weight(dirs)` (global `(N, K)`);
/// returns this rank's shard of `C` in `Layout3D::output(dirs)`.
pub fn mm_nn(ep: &mut Endpoint, ctx: &Ctx3D, a: &Tensor, b: &Tensor, dirs: Dirs) -> Tensor {
    dirs.assert_distinct();
    let a_full = gather_merge(ep, ctx, a, Layout3D::input(dirs), dirs.a); // (M/p, N/p)
    let b_full = gather_merge(ep, ctx, b, Layout3D::weight(dirs), dirs.b); // (N/p, K/p)
    let (m, k) = a_full.dims2();
    let n = b_full.dims2().1;
    let partial = a_full.matmul(&b_full); // (M/p, K/p)
    charge_mm(ep, m, n, k);
    reduce_scatter_split(ep, ctx, partial, dirs.c, true)
}

/// **Algorithm 2** (backward `C = AB`): given `dc` in output layout and the
/// forward operands, returns `(dA, dB)` in the operands' own layouts.
///
/// `Ȧ = Ċ·Bᵀ` runs with directions `(z, x, y)`; `Ḃ = Aᵀ·Ċ` with
/// `(y, z, x)` — both reuse the `Ċ` gathered along `z`.
pub fn mm_nn_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    a: &Tensor,
    b: &Tensor,
    dirs: Dirs,
) -> (Tensor, Tensor) {
    dirs.assert_distinct();
    // Shared gather: Ċ along dC merges the output's inner row split.
    let dc_full = gather_merge(ep, ctx, dc, Layout3D::output(dirs), dirs.c); // (M/p, K/p)
    let da = da_from_dc_full(ep, ctx, &dc_full, b, dirs);
    let db = db_from_dc_full(ep, ctx, &dc_full, a, dirs);
    (da, db)
}

/// `Ȧ = Ċ·Bᵀ` from the already-gathered `Ċ`: gather B along dB (merging
/// its inner col split), local NT, reduce-scatter along dA splitting rows
/// → input layout.
fn da_from_dc_full(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc_full: &Tensor,
    b: &Tensor,
    dirs: Dirs,
) -> Tensor {
    let b_full = gather_merge(ep, ctx, b, Layout3D::weight(dirs), dirs.b); // (N/p, K/p)
    {
        let (m, kk) = dc_full.dims2();
        let n = b_full.dims2().0;
        charge_mm(ep, m, n, kk);
    }
    let da_partial = dc_full.matmul_nt(&b_full); // (M/p, N/p)
    reduce_scatter_split(ep, ctx, da_partial, dirs.a, true)
}

/// `Ḃ = Aᵀ·Ċ` from the already-gathered `Ċ`: gather A along dA, local TN,
/// reduce-scatter along dB splitting *columns* → weight layout (cols split
/// `Two(dA, dB)`).
fn db_from_dc_full(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc_full: &Tensor,
    a: &Tensor,
    dirs: Dirs,
) -> Tensor {
    let a_full = gather_merge(ep, ctx, a, Layout3D::input(dirs), dirs.a); // (M/p, N/p)
    {
        let (m, n) = a_full.dims2();
        let kk = dc_full.dims2().1;
        charge_mm(ep, n, kk, m);
    }
    let db_partial = a_full.matmul_tn(dc_full); // (N/p, K/p)
    reduce_scatter_split(ep, ctx, db_partial, dirs.b, false)
}

/// The `Ȧ = Ċ·Bᵀ` half of Algorithm 2 on its own — the standalone
/// input-gradient form ([`crate::parallel::ParallelOps::matmul_nt`]).
/// [`mm_nn_backward`] fuses both halves to share the `Ċ` gather; use it
/// when both gradients are needed.
pub fn mm_nn_backward_da(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    b: &Tensor,
    dirs: Dirs,
) -> Tensor {
    dirs.assert_distinct();
    let dc_full = gather_merge(ep, ctx, dc, Layout3D::output(dirs), dirs.c); // (M/p, K/p)
    da_from_dc_full(ep, ctx, &dc_full, b, dirs)
}

/// The `Ḃ = Aᵀ·Ċ` half of Algorithm 2 on its own — the standalone
/// weight-gradient form ([`crate::parallel::ParallelOps::matmul_tn`]).
pub fn mm_nn_backward_db(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    a: &Tensor,
    dirs: Dirs,
) -> Tensor {
    dirs.assert_distinct();
    let dc_full = gather_merge(ep, ctx, dc, Layout3D::output(dirs), dirs.c); // (M/p, K/p)
    db_from_dc_full(ep, ctx, &dc_full, a, dirs)
}

// ---------------------------------------------------------------------
// Algorithm 3 & 4 — C = A·Bᵀ
// ---------------------------------------------------------------------

/// **Algorithm 3** (forward `C = A·Bᵀ`): `a` in input layout (global
/// `(M, N)`), `b` in [`Layout3DExt::nt_rhs`] layout (global `(K, N)`);
/// returns `C` (global `(M, K)`) in output layout.
pub fn mm_nt(ep: &mut Endpoint, ctx: &Ctx3D, a: &Tensor, b: &Tensor, dirs: Dirs) -> Tensor {
    dirs.assert_distinct();
    let a_full = gather_merge(ep, ctx, a, Layout3D::input(dirs), dirs.a); // (M/p, N/p)
    let b_full = gather_merge(ep, ctx, b, Layout3D::nt_rhs(dirs), dirs.b); // (K/p, N/p)
    let (m, n) = a_full.dims2();
    let kk = b_full.dims2().0;
    let partial = a_full.matmul_nt(&b_full); // (M/p, K/p)
    charge_mm(ep, m, kk, n);
    reduce_scatter_split(ep, ctx, partial, dirs.c, true)
}

/// **Algorithm 4** (backward `C = A·Bᵀ`): `Ȧ = Ċ·B` in `(z, x, y)`,
/// `Ḃ = Ċᵀ·A` in `(z, y, x)`.
pub fn mm_nt_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    a: &Tensor,
    b: &Tensor,
    dirs: Dirs,
) -> (Tensor, Tensor) {
    dirs.assert_distinct();
    let dc_full = gather_merge(ep, ctx, dc, Layout3D::output(dirs), dirs.c); // (M/p, K/p)

    // Ȧ = Ċ·B : gather B along dB merging rows, local NN,
    // reduce-scatter along dA splitting rows -> input layout.
    let b_full = gather_merge(ep, ctx, b, Layout3D::nt_rhs(dirs), dirs.b); // (K/p, N/p)
    {
        let (m, kk) = dc_full.dims2();
        let n = b_full.dims2().1;
        charge_mm(ep, m, n, kk);
    }
    let da_partial = dc_full.matmul(&b_full); // (M/p, N/p)
    let da = reduce_scatter_split(ep, ctx, da_partial, dirs.a, true);

    // Ḃ = Ċᵀ·A : gather A along dA, local TN, reduce-scatter along dB
    // splitting rows -> nt_rhs layout (rows split Two(dA, dB)).
    let a_full = gather_merge(ep, ctx, a, Layout3D::input(dirs), dirs.a); // (M/p, N/p)
    {
        let (m, kk) = dc_full.dims2();
        let n = a_full.dims2().1;
        charge_mm(ep, kk, n, m);
    }
    let db_partial = dc_full.matmul_tn(&a_full); // (K/p, N/p)
    let db = reduce_scatter_split(ep, ctx, db_partial, dirs.b, true);

    (da, db)
}

// ---------------------------------------------------------------------
// Algorithm 5 & 6 — C = Aᵀ·B
// ---------------------------------------------------------------------

/// **Algorithm 5** (forward `C = Aᵀ·B`): `a` in [`Layout3DExt::tn_lhs`]
/// layout (global `(N, M)`), `b` in weight layout (global `(N, K)`);
/// returns `C` (global `(M, K)`) in output layout.
pub fn mm_tn(ep: &mut Endpoint, ctx: &Ctx3D, a: &Tensor, b: &Tensor, dirs: Dirs) -> Tensor {
    dirs.assert_distinct();
    let a_full = gather_merge(ep, ctx, a, Layout3D::tn_lhs(dirs), dirs.a); // (N/p, M/p)
    let b_full = gather_merge(ep, ctx, b, Layout3D::weight(dirs), dirs.b); // (N/p, K/p)
    let (n, m) = a_full.dims2();
    let kk = b_full.dims2().1;
    let partial = a_full.matmul_tn(&b_full); // (M/p, K/p)
    charge_mm(ep, m, kk, n);
    reduce_scatter_split(ep, ctx, partial, dirs.c, true)
}

/// **Algorithm 6** (backward `C = Aᵀ·B`): `Ȧ = B·Ċᵀ` in `(x, z, y)`,
/// `Ḃ = A·Ċ` in `(y, z, x)`.
pub fn mm_tn_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    a: &Tensor,
    b: &Tensor,
    dirs: Dirs,
) -> (Tensor, Tensor) {
    dirs.assert_distinct();
    let dc_full = gather_merge(ep, ctx, dc, Layout3D::output(dirs), dirs.c); // (M/p, K/p)

    // Ȧ = B·Ċᵀ : (N/p, K/p)·(M/p, K/p)ᵀ = (N/p, M/p); reduce-scatter along
    // dA splitting *columns* -> tn_lhs layout (cols split Two(dB, dA)).
    let b_full = gather_merge(ep, ctx, b, Layout3D::weight(dirs), dirs.b); // (N/p, K/p)
    {
        let (n, kk) = b_full.dims2();
        let m = dc_full.dims2().0;
        charge_mm(ep, n, m, kk);
    }
    let da_partial = b_full.matmul_nt(&dc_full); // (N/p, M/p)
    let da = reduce_scatter_split(ep, ctx, da_partial, dirs.a, false);

    // Ḃ = A·Ċ : (N/p, M/p)·(M/p, K/p) = (N/p, K/p); reduce-scatter along dB
    // splitting *columns* -> weight layout (cols split Two(dA, dB)).
    let a_full = gather_merge(ep, ctx, a, Layout3D::tn_lhs(dirs), dirs.a); // (N/p, M/p)
    {
        let (n, m) = a_full.dims2();
        let kk = dc_full.dims2().1;
        charge_mm(ep, n, kk, m);
    }
    let db_partial = a_full.matmul(&dc_full); // (N/p, K/p)
    let db = reduce_scatter_split(ep, ctx, db_partial, dirs.b, false);

    (da, db)
}

// ---------------------------------------------------------------------
// Algorithms 7 & 8 — matrix-vector operations (bias add, scale)
// ---------------------------------------------------------------------

/// Materialize the full column-chunk `b_chunk_full` of a diagonally stored
/// vector at every rank (the broadcast + all-gather prefix shared by
/// Algorithms 7/8 and their `*` variants).
///
/// `b_chunk` is `Some(chunk)` on diagonal owners (`coord(dirs.a) ==
/// coord(dirs.c)`), `None` elsewhere. Returns the length-`cols(shard)`
/// vector aligned with the rank's activation shard (input layout).
pub fn gather_vec(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    b_chunk: Option<&Tensor>,
    dirs: Dirs,
) -> Tensor {
    // Broadcast along dA from the diagonal owner of this line. The owner of
    // the dA-line through this coord is the member with coord(dirs.a) ==
    // coord(dirs.c) — exactly `DiagVec3D::for_dirs(dirs).owns(..)`.
    debug_assert_eq!(
        DiagVec3D::for_dirs(dirs).owns(ctx.coord),
        ctx.coord.axis(dirs.a) == ctx.coord.axis(dirs.c)
    );
    let line_a = ctx.line(dirs.a);
    let root_pos = ctx.coord.axis(dirs.c);
    let mine = if ctx.cube.pos_in_line(ctx.coord, dirs.a) == root_pos {
        Some(
            b_chunk
                .expect("diagonal owner must supply its vector chunk")
                .clone(),
        )
    } else {
        assert!(b_chunk.is_none(), "off-diagonal rank must pass None");
        None
    };
    let chunk = broadcast(ep, &line_a, root_pos, mine);
    // All-gather along dB and flatten into the full per-column-block vector.
    let line_b = ctx.line(dirs.b);
    let parts = all_gather(ep, &line_b, &chunk);
    if parts.iter().any(|p| p.is_phantom()) {
        let n: usize = parts.iter().map(|p| p.numel()).sum();
        return Tensor::phantom(&[n]);
    }
    let mut flat = Vec::new();
    for p in &parts {
        flat.extend_from_slice(p.data());
    }
    let n = flat.len();
    Tensor::from_vec(&[n], flat)
}

/// **Algorithm 7** (forward `C = A + b`): `a` in input layout, `b_chunk` the
/// diagonal shard (or `None` off-diagonal). Also used for `C = A * b` via
/// `mul = true` (the layernorm γ path).
pub fn vec_op(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    a: &Tensor,
    b_chunk: Option<&Tensor>,
    dirs: Dirs,
    mul: bool,
) -> Tensor {
    let b_full = gather_vec(ep, ctx, b_chunk, dirs);
    ep.charge_memop(a.nominal_bytes() as f64);
    if mul {
        a.mul_row_vector(&b_full)
    } else {
        a.add_row_vector(&b_full)
    }
}

/// **Algorithm 8** (backward `C = A + b`): returns `(Ȧ, ḃ)` where `ḃ` is
/// `Some(chunk)` only on diagonal owners. `Ȧ = Ċ`; `ḃ` is the column-sum of
/// `Ċ` reduced over the dA line to the diagonal owner, then reduce-scattered
/// over the dB line so each owner keeps its chunk.
pub fn add_vec_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    dirs: Dirs,
) -> (Tensor, Option<Tensor>) {
    let db = vec_grad(ep, ctx, dc, dirs);
    (dc.clone(), db)
}

/// Backward of `C = A * b`: `Ȧ = Ċ * b` (per-column), `ḃ = Σ_rows (Ċ ⊙ A)`.
pub fn mul_vec_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dc: &Tensor,
    a: &Tensor,
    b_chunk: Option<&Tensor>,
    dirs: Dirs,
) -> (Tensor, Option<Tensor>) {
    let b_full = gather_vec(ep, ctx, b_chunk, dirs);
    ep.charge_memop(2.0 * dc.nominal_bytes() as f64);
    let da = dc.mul_row_vector(&b_full);
    let db = vec_grad(ep, ctx, &dc.mul(a), dirs);
    (da, db)
}

/// Shared reduction path of Algorithm 8: column-sum `g` locally, reduce over
/// the dA line to the diagonal owner, reduce-scatter over the dB line.
fn vec_grad(ep: &mut Endpoint, ctx: &Ctx3D, g: &Tensor, dirs: Dirs) -> Option<Tensor> {
    let p = ctx.p();
    ep.charge_memop(g.nominal_bytes() as f64);
    let local = g.sum_rows(); // (cols,)
    // Reduce along dA to the diagonal member (pos = coord(dirs.c)).
    let line_a = ctx.line(dirs.a);
    let root_pos = ctx.coord.axis(dirs.c);
    let at_diag = reduce(ep, &line_a, root_pos, &local);
    // Diagonal owners split the column-block vector over the dB line and
    // reduce-scatter; off-diagonal ranks return None. NOTE: the dB-line of a
    // diagonal rank consists entirely of diagonal ranks (dA and dC coords
    // are shared along the dB line), so the collective's participants agree.
    match at_diag {
        Some(v) => {
            let line_b = ctx.line(dirs.b);
            let n = v.numel();
            assert_eq!(n % p, 0);
            let chunks = v.reshape(&[p, n / p]).split_rows(p);
            let chunks: Vec<Tensor> = chunks
                .into_iter()
                .map(|c| {
                    let len = c.numel();
                    c.into_reshape(&[len])
                })
                .collect();
            Some(reduce_scatter(ep, &line_b, chunks))
        }
        None => None,
    }
}

// ---------------------------------------------------------------------
// 3-D layer normalization (§3.2: "3-D layer normalization ... only applies
// matrix-vector adds and multiplications with the parameters γ and β")
// ---------------------------------------------------------------------

/// Forward 3-D layernorm over the hidden (column) dimension of an
/// input-laid-out activation. Statistics need the full row, whose columns
/// are split along `dirs.c`, so mean/var are computed with one all-reduce of
/// the stacked (sum, sumsq) vectors over the dC line. γ and β are diagonal
/// vectors applied via Algorithm 7's machinery.
///
/// Returns `(y, xhat, inv_std)` — the latter two are saved for backward.
pub fn layernorm(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    x: &Tensor,
    gamma_chunk: Option<&Tensor>,
    beta_chunk: Option<&Tensor>,
    dirs: Dirs,
    eps: f32,
    n_global_cols: usize,
) -> (Tensor, Tensor, Tensor) {
    let (rows, _cols) = x.dims2();
    let line_c = ctx.line(dirs.c);
    // Stack local row-sums and row-sumsqs into one tensor -> one all-reduce.
    let stats = if x.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        let sums = x.sum_cols();
        let sumsq = x.map(|v| v * v).sum_cols();
        s.set_block(0, 0, &sums.reshape(&[1, rows]));
        s.set_block(1, 0, &sumsq.reshape(&[1, rows]));
        s
    };
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);
    let stats = crate::collectives::all_reduce(ep, &line_c, &stats);
    let n = n_global_cols as f32;
    let (xhat, inv_std) = if stats.is_phantom() || x.is_phantom() {
        (Tensor::phantom(x.shape()), Tensor::phantom(&[rows]))
    } else {
        let mut xh = x.clone();
        let mut istd = vec![0.0f32; rows];
        {
            let sd = stats.data().to_vec();
            let cols = x.dims2().1;
            let xd = xh.data_mut();
            for r in 0..rows {
                let mean = sd[r] / n;
                let var = (sd[rows + r] / n - mean * mean).max(0.0);
                let inv = 1.0 / (var + eps).sqrt();
                istd[r] = inv;
                for c in 0..cols {
                    xd[r * cols + c] = (xd[r * cols + c] - mean) * inv;
                }
            }
        }
        (xh, Tensor::from_vec(&[rows], istd))
    };
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);
    // y = xhat * γ + β  (both diagonal vectors, Algorithm 7 machinery).
    let scaled = vec_op(ep, ctx, &xhat, gamma_chunk, dirs, true);
    let y = vec_op(ep, ctx, &scaled, beta_chunk, dirs, false);
    (y, xhat, inv_std)
}

/// Backward 3-D layernorm. Given upstream `dy` and the saved `(xhat,
/// inv_std)`, returns `(dx, dγ, dβ)` with the vector grads on diagonal
/// owners only.
///
/// Uses the standard layernorm VJP:
/// `dx = inv_std/N · (N·g − Σg − xhat·Σ(g⊙xhat))` with `g = dy ⊙ γ`,
/// where the two row-reductions are all-reduced over the dC line.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_backward(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma_chunk: Option<&Tensor>,
    dirs: Dirs,
    n_global_cols: usize,
) -> (Tensor, Option<Tensor>, Option<Tensor>) {
    let (rows, cols) = dy.dims2();
    // dβ = Σ_rows dy ; dγ = Σ_rows (dy ⊙ xhat) — Algorithm 8 reduction path.
    let dbeta = vec_grad(ep, ctx, dy, dirs);
    let dgamma = vec_grad(ep, ctx, &dy.mul(xhat), dirs);

    // g = dy ⊙ γ (γ materialized at full column-block via Algorithm 7 prefix)
    let gamma_full = gather_vec(ep, ctx, gamma_chunk, dirs);
    let g = dy.mul_row_vector(&gamma_full);
    ep.charge_memop(3.0 * dy.nominal_bytes() as f64);

    // Row reductions of g and g ⊙ xhat, all-reduced over the dC line.
    let line_c = ctx.line(dirs.c);
    let stats = if g.is_phantom() || xhat.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        s.set_block(0, 0, &g.sum_cols().reshape(&[1, rows]));
        s.set_block(1, 0, &g.mul(xhat).sum_cols().reshape(&[1, rows]));
        s
    };
    let stats = crate::collectives::all_reduce(ep, &line_c, &stats);
    let n = n_global_cols as f32;
    let dx = if g.is_phantom() || stats.is_phantom() || inv_std.is_phantom() {
        Tensor::phantom(dy.shape())
    } else {
        let sd = stats.data();
        let istd = inv_std.data();
        let gd = g.data();
        let xd = xhat.data();
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let sum_g = sd[r];
            let sum_gx = sd[rows + r];
            let c0 = istd[r] / n;
            for c in 0..cols {
                let idx = r * cols + c;
                out[idx] = c0 * (n * gd[idx] - sum_g - xd[idx] * sum_gx);
            }
        }
        Tensor::from_vec(&[rows, cols], out)
    };
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);
    (dx, dgamma, dbeta)
}

/// The `dx` half of [`layernorm_backward`] on its own — the micro-batch
/// pipelining path. The float operations duplicate the joint routine's
/// `dx` part verbatim (γ materialization, stacked-stats all-reduce over
/// the dC line, per-row VJP loop); the joint path is deliberately left
/// untouched so its clock charges stay bit-stable for the costmodel pins.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_backward_dx(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma_chunk: Option<&Tensor>,
    dirs: Dirs,
    n_global_cols: usize,
) -> Tensor {
    let (rows, cols) = dy.dims2();
    let gamma_full = gather_vec(ep, ctx, gamma_chunk, dirs);
    let g = dy.mul_row_vector(&gamma_full);
    ep.charge_memop(3.0 * dy.nominal_bytes() as f64);
    let line_c = ctx.line(dirs.c);
    let stats = if g.is_phantom() || xhat.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        s.set_block(0, 0, &g.sum_cols().reshape(&[1, rows]));
        s.set_block(1, 0, &g.mul(xhat).sum_cols().reshape(&[1, rows]));
        s
    };
    let stats = crate::collectives::all_reduce(ep, &line_c, &stats);
    let n = n_global_cols as f32;
    let dx = if g.is_phantom() || stats.is_phantom() || inv_std.is_phantom() {
        Tensor::phantom(dy.shape())
    } else {
        let sd = stats.data();
        let istd = inv_std.data();
        let gd = g.data();
        let xd = xhat.data();
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let sum_g = sd[r];
            let sum_gx = sd[rows + r];
            let c0 = istd[r] / n;
            for c in 0..cols {
                let idx = r * cols + c;
                out[idx] = c0 * (n * gd[idx] - sum_g - xd[idx] * sum_gx);
            }
        }
        Tensor::from_vec(&[rows, cols], out)
    };
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);
    dx
}

/// The paper's semantics for the trait: a `stage` linear is Algorithm 1
/// under [`Ctx3D::stage_dirs`] with its bias applied by Algorithm 7 under
/// the *output* directions; backward is Algorithm 8 then Algorithm 2 (the
/// fused form, sharing the `dY` gather). Layernorm and `vec_op` operate on
/// entry-layout (`input(d0)`) activations.
impl ParallelOps for Ctx3D {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        mm_nn(ep, self, x, w, self.stage_dirs(stage))
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        mm_nn_backward_da(ep, self, dy, w, self.stage_dirs(stage))
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, stage: Stage) -> Tensor {
        mm_nn_backward_db(ep, self, dy, x, self.stage_dirs(stage))
    }

    fn matmul_nn_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor) {
        mm_nn_backward(ep, self, dy, x, w, self.stage_dirs(stage))
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        let dirs = self.stage_dirs(stage);
        let y = mm_nn(ep, self, x, w, dirs);
        // Bias lives on the diagonal of the *output* directions (Fig. 5).
        vec_op(ep, self, &y, b, dirs.swapped(), false)
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        let dirs = self.stage_dirs(stage);
        // Algorithm 8 under the output directions, then the fused
        // Algorithm 2 (shared dY gather) under the layer's own directions.
        let (d_mm, db) = add_vec_backward(ep, self, dy, dirs.swapped());
        let (dx, dw) = mm_nn_backward(ep, self, &d_mm, x, w, dirs);
        (dx, dw, db)
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        vec_op(ep, self, a, v, self.d0, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        layernorm(ep, self, x, gamma, beta, self.d0, eps, hidden)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        layernorm_backward(ep, self, dy, xhat, inv_std, gamma, self.d0, hidden)
    }

    // Split backward halves (micro-batch pipelining). `linear_bwd_dx`
    // keeps its default (`matmul_nt` = Algorithm 2's Ȧ half); the
    // parameter halves mirror `linear_bwd` / `layernorm_backward` exactly
    // — same Algorithm 8 reductions, same order — minus the input-grad
    // work.

    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        let dirs = self.stage_dirs(stage);
        // Bias grad first (Algorithm 8's reduction under the output
        // directions), mirroring `linear_bwd`'s order; then the Ḃ half of
        // Algorithm 2 under the layer's own directions.
        let db = vec_grad(ep, self, dy, dirs.swapped());
        let dw = mm_nn_backward_db(ep, self, dy, x, dirs);
        (dw, db)
    }

    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> Tensor {
        layernorm_backward_dx(ep, self, dy, xhat, inv_std, gamma, self.d0, hidden)
    }

    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        // Same order as `layernorm_backward`: dβ first, then dγ.
        let dbeta = vec_grad(ep, self, dy, self.d0);
        let dgamma = vec_grad(ep, self, &dy.mul(xhat), self.d0);
        (dgamma, dbeta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;
    use crate::topology::Axis;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    /// Dense global reference for C = A·B, scattered/compared shard-wise.
    fn check_mm_nn(p: usize, m: usize, n: usize, k: usize, dirs: Dirs) {
        let cube = Cube::new(p);
        let a = randt(&[m, n], 1);
        let b = randt(&[n, k], 2);
        let c_ref = a.matmul(&b);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
        let world = p * p * p;
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_nn(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
        });
        let got = Layout3D::output(dirs).gather(&cube, &out, m, k);
        assert!(
            got.max_abs_diff(&c_ref) < 1e-3,
            "mm_nn mismatch p={p} dirs={dirs:?}"
        );
    }

    #[test]
    fn algorithm1_matches_dense_p2() {
        check_mm_nn(2, 8, 12, 16, Dirs::canonical());
    }

    #[test]
    fn algorithm1_matches_dense_swapped_dirs() {
        check_mm_nn(2, 8, 12, 16, Dirs::canonical().swapped());
    }

    #[test]
    fn algorithm1_matches_dense_p1_degenerate() {
        check_mm_nn(1, 4, 4, 4, Dirs::canonical());
    }

    #[test]
    fn algorithm1_exotic_dirs() {
        // Any permutation of distinct axes must work.
        check_mm_nn(2, 8, 8, 8, Dirs { a: Axis::X, b: Axis::Z, c: Axis::Y });
    }

    #[test]
    fn algorithm2_matches_dense_gradients() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n, k) = (8, 12, 16);
        let a = randt(&[m, n], 3);
        let b = randt(&[n, k], 4);
        let dc = randt(&[m, k], 5);
        // Dense reference: dA = dC·Bᵀ, dB = Aᵀ·dC (paper Eq. 3).
        let da_ref = dc.matmul_nt(&b);
        let db_ref = a.matmul_tn(&dc);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
        let dc_shards = Layout3D::output(dirs).scatter(&cube, &dc);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_nn_backward(ep, &ctx, &dc_shards[rank], &a_shards[rank], &b_shards[rank], dirs)
        });
        let da_shards: Vec<Tensor> = out.iter().map(|(da, _)| da.clone()).collect();
        let db_shards: Vec<Tensor> = out.iter().map(|(_, db)| db.clone()).collect();
        let da = Layout3D::input(dirs).gather(&cube, &da_shards, m, n);
        let db = Layout3D::weight(dirs).gather(&cube, &db_shards, n, k);
        assert!(da.max_abs_diff(&da_ref) < 1e-3, "dA mismatch");
        assert!(db.max_abs_diff(&db_ref) < 1e-3, "dB mismatch");
    }

    #[test]
    fn algorithm3_nt_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n, k) = (8, 12, 16); // A (m,n), B (k,n), C (m,k)
        let a = randt(&[m, n], 6);
        let b = randt(&[k, n], 7);
        let c_ref = a.matmul_nt(&b);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::nt_rhs(dirs).scatter(&cube, &b);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_nt(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
        });
        let got = Layout3D::output(dirs).gather(&cube, &out, m, k);
        assert!(got.max_abs_diff(&c_ref) < 1e-3);
    }

    #[test]
    fn algorithm4_nt_backward_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n, k) = (8, 12, 16);
        let a = randt(&[m, n], 8);
        let b = randt(&[k, n], 9);
        let dc = randt(&[m, k], 10);
        // Paper Eq. 4: dA = dC·B, dB = dCᵀ·A.
        let da_ref = dc.matmul(&b);
        let db_ref = dc.matmul_tn(&a);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::nt_rhs(dirs).scatter(&cube, &b);
        let dc_shards = Layout3D::output(dirs).scatter(&cube, &dc);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_nt_backward(ep, &ctx, &dc_shards[rank], &a_shards[rank], &b_shards[rank], dirs)
        });
        let da_shards: Vec<Tensor> = out.iter().map(|(da, _)| da.clone()).collect();
        let db_shards: Vec<Tensor> = out.iter().map(|(_, db)| db.clone()).collect();
        let da = Layout3D::input(dirs).gather(&cube, &da_shards, m, n);
        let db = Layout3D::nt_rhs(dirs).gather(&cube, &db_shards, k, n);
        assert!(da.max_abs_diff(&da_ref) < 1e-3, "dA mismatch");
        assert!(db.max_abs_diff(&db_ref) < 1e-3, "dB mismatch");
    }

    #[test]
    fn algorithm5_tn_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n, k) = (8, 12, 16); // A (n,m), B (n,k), C (m,k)
        let a = randt(&[n, m], 11);
        let b = randt(&[n, k], 12);
        let c_ref = a.matmul_tn(&b);
        let a_shards = Layout3D::tn_lhs(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_tn(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
        });
        let got = Layout3D::output(dirs).gather(&cube, &out, m, k);
        assert!(got.max_abs_diff(&c_ref) < 1e-3);
    }

    #[test]
    fn algorithm6_tn_backward_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n, k) = (8, 12, 16);
        let a = randt(&[n, m], 13);
        let b = randt(&[n, k], 14);
        let dc = randt(&[m, k], 15);
        // Paper Eq. 5: dA = B·dCᵀ, dB = A·dC.
        let da_ref = b.matmul_nt(&dc);
        let db_ref = a.matmul(&dc);
        let a_shards = Layout3D::tn_lhs(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
        let dc_shards = Layout3D::output(dirs).scatter(&cube, &dc);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_tn_backward(ep, &ctx, &dc_shards[rank], &a_shards[rank], &b_shards[rank], dirs)
        });
        let da_shards: Vec<Tensor> = out.iter().map(|(da, _)| da.clone()).collect();
        let db_shards: Vec<Tensor> = out.iter().map(|(_, db)| db.clone()).collect();
        let da = Layout3D::tn_lhs(dirs).gather(&cube, &da_shards, n, m);
        let db = Layout3D::weight(dirs).gather(&cube, &db_shards, n, k);
        assert!(da.max_abs_diff(&da_ref) < 1e-3, "dA mismatch");
        assert!(db.max_abs_diff(&db_ref) < 1e-3, "dB mismatch");
    }

    #[test]
    fn algorithm7_vector_add_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n) = (8, 12);
        let a = randt(&[m, n], 16);
        let v = randt(&[n], 17);
        let c_ref = a.add_row_vector(&v);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let v_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &v);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            vec_op(ep, &ctx, &a_shards[rank], v_shards[rank].as_ref(), dirs, false)
        });
        let got = Layout3D::input(dirs).gather(&cube, &out, m, n);
        assert!(got.max_abs_diff(&c_ref) < 1e-5);
    }

    #[test]
    fn algorithm7_vector_mul_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical().swapped();
        let cube = Cube::new(p);
        let (m, n) = (4, 8);
        let a = randt(&[m, n], 18);
        let v = randt(&[n], 19);
        let c_ref = a.mul_row_vector(&v);
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let v_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &v);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            vec_op(ep, &ctx, &a_shards[rank], v_shards[rank].as_ref(), dirs, true)
        });
        let got = Layout3D::input(dirs).gather(&cube, &out, m, n);
        assert!(got.max_abs_diff(&c_ref) < 1e-5);
    }

    #[test]
    fn algorithm8_bias_grad_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n) = (8, 12);
        let dc = randt(&[m, n], 20);
        let db_ref = dc.sum_rows();
        let dc_shards = Layout3D::input(dirs).scatter(&cube, &dc);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            add_vec_backward(ep, &ctx, &dc_shards[rank], dirs)
        });
        let da_shards: Vec<Tensor> = out.iter().map(|(da, _)| da.clone()).collect();
        let db_shards: Vec<Option<Tensor>> = out.iter().map(|(_, db)| db.clone()).collect();
        // dA must equal dC shard-for-shard.
        let da = Layout3D::input(dirs).gather(&cube, &da_shards, m, n);
        assert!(da.max_abs_diff(&dc) < 1e-6);
        let db = DiagVec3D::for_dirs(dirs).gather(&cube, &db_shards, n);
        assert!(db.max_abs_diff(&db_ref) < 1e-4, "db {:?} vs {:?}", db, db_ref);
    }

    #[test]
    fn mul_vec_backward_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n) = (8, 12);
        let a = randt(&[m, n], 21);
        let v = randt(&[n], 22);
        let dc = randt(&[m, n], 23);
        let da_ref = dc.mul_row_vector(&v);
        let dv_ref = dc.mul(&a).sum_rows();
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let v_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &v);
        let dc_shards = Layout3D::input(dirs).scatter(&cube, &dc);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mul_vec_backward(
                ep, &ctx, &dc_shards[rank], &a_shards[rank], v_shards[rank].as_ref(), dirs,
            )
        });
        let da_shards: Vec<Tensor> = out.iter().map(|(da, _)| da.clone()).collect();
        let dv_shards: Vec<Option<Tensor>> = out.iter().map(|(_, dv)| dv.clone()).collect();
        let da = Layout3D::input(dirs).gather(&cube, &da_shards, m, n);
        let dv = DiagVec3D::for_dirs(dirs).gather(&cube, &dv_shards, n);
        assert!(da.max_abs_diff(&da_ref) < 1e-4);
        assert!(dv.max_abs_diff(&dv_ref) < 1e-4);
    }

    #[test]
    fn layernorm_matches_dense() {
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n) = (8, 16);
        let x = randt(&[m, n], 24);
        let gamma = randt(&[n], 25).map(|v| 1.0 + 0.1 * v);
        let beta = randt(&[n], 26).scale(0.1);
        let eps = 1e-5f32;
        // Dense reference.
        let mut y_ref = Tensor::zeros(&[m, n]);
        for r in 0..m {
            let row: Vec<f32> = (0..n).map(|c| x.at2(r, c)).collect();
            let mean = row.iter().sum::<f32>() / n as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for c in 0..n {
                y_ref.data_mut()[r * n + c] =
                    (row[c] - mean) * inv * gamma.data()[c] + beta.data()[c];
            }
        }
        let x_shards = Layout3D::input(dirs).scatter(&cube, &x);
        let g_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &gamma);
        let b_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &beta);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let (y, _, _) = layernorm(
                ep, &ctx, &x_shards[rank], g_shards[rank].as_ref(), b_shards[rank].as_ref(),
                dirs, eps, n,
            );
            y
        });
        let got = Layout3D::input(dirs).gather(&cube, &out, m, n);
        assert!(got.max_abs_diff(&y_ref) < 1e-3);
    }

    #[test]
    fn layernorm_backward_matches_numeric_gradient() {
        // Finite-difference check of dx through the distributed layernorm.
        let p = 2;
        let dirs = Dirs::canonical();
        let cube = Cube::new(p);
        let (m, n) = (4, 8);
        let x = randt(&[m, n], 27);
        let gamma = randt(&[n], 28).map(|v| 1.0 + 0.1 * v);
        let beta = Tensor::zeros(&[n]);
        let dy = randt(&[m, n], 29);
        let eps = 1e-5f32;

        let gamma2 = gamma.clone();
        let beta2 = beta.clone();
        let cube2 = cube.clone();
        let run_fwd = move |xin: &Tensor| -> Tensor {
            let cube = cube2.clone();
            let x_shards = Layout3D::input(dirs).scatter(&cube, xin);
            let g_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &gamma2);
            let b_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &beta2);
            let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
                let ctx = Ctx3D::new(Cube::new(p), rank);
                layernorm(
                    ep, &ctx, &x_shards[rank], g_shards[rank].as_ref(),
                    b_shards[rank].as_ref(), dirs, eps, n,
                )
                .0
            });
            Layout3D::input(dirs).gather(&cube, &out, m, n)
        };

        // Analytic dx via the distributed backward.
        let x_shards = Layout3D::input(dirs).scatter(&cube, &x);
        let g_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &gamma);
        let b_shards = DiagVec3D::for_dirs(dirs).scatter(&cube, &beta);
        let dy_shards = Layout3D::input(dirs).scatter(&cube, &dy);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let (_, xhat, istd) = layernorm(
                ep, &ctx, &x_shards[rank], g_shards[rank].as_ref(), b_shards[rank].as_ref(),
                dirs, eps, n,
            );
            let g2 = DiagVec3D::for_dirs(dirs).scatter(&Cube::new(p), &gamma);
            layernorm_backward(
                ep, &ctx, &dy_shards[rank], &xhat, &istd, g2[rank].as_ref(), dirs, n,
            )
            .0
        });
        let dx = Layout3D::input(dirs).gather(&cube, &out, m, n);

        // Numeric gradient: (f(x+h·e) - f(x-h·e))/2h dotted with dy.
        let h = 1e-2f32;
        for &(r, c) in &[(0usize, 0usize), (1, 3), (3, 7), (2, 5)] {
            let mut xp = x.clone();
            xp.data_mut()[r * n + c] += h;
            let mut xm = x.clone();
            xm.data_mut()[r * n + c] -= h;
            let fp = run_fwd(&xp);
            let fm = run_fwd(&xm);
            let num = fp.sub(&fm).scale(1.0 / (2.0 * h)).mul(&dy).sum();
            let ana = dx.at2(r, c);
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "dx[{r},{c}] numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn phantom_mode_flows_through_algorithm1() {
        let p = 2;
        let dirs = Dirs::canonical();
        let out = run_spmd(8, NetModel::longhorn_v100(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            // Paper-scale-ish shard shapes, phantom data.
            let a = Tensor::phantom(&[128, 1024]); // (M/p², N/p)
            let b = Tensor::phantom(&[1024, 128]); // (N/p, K/p²)
            let c = mm_nn(ep, &ctx, &a, &b, dirs);
            (c.is_phantom(), c.shape().to_vec(), ep.clock)
        });
        for (ph, shape, clock) in out {
            assert!(ph);
            // a: (M/p², N/p) = (128, 1024) → M = 512; b: (N/p, K/p²) =
            // (1024, 128) → K = 512; output shard (M/p², K/p) = (128, 256).
            assert_eq!(shape, vec![128, 256]);
            assert!(clock > 0.0, "virtual time must advance in phantom mode");
        }
    }
}
