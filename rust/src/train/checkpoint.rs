//! Rank-sharded checkpoints (the Megatron-style layout: each rank persists
//! its own shards; restore requires the same topology).
//!
//! Own binary format (no serde offline), version 2:
//! `magic "CUBIC1\n" · u32 version · u32 tensor count · per tensor
//! { u32 name_len · name utf8 · u32 ndims · u64 dims… · f32 data… ·
//! u64 fnv1a checksum }`, all little-endian. The checksum covers the
//! tensor's name, dims and data bytes, so a single flipped bit anywhere in
//! a record is detected. Absent optional tensors (non-owner vector
//! shards) are simply not written; load distinguishes presence by name.
//!
//! Writes are **crash-consistent**: the file is assembled under a sibling
//! `.tmp` name and published with an atomic `rename`, so a crash mid-save
//! leaves the previous checkpoint intact and a reader can never observe a
//! torn file. Truncation and corruption surface as typed `Err`s from
//! [`read_tensors`]/[`load_rank`], never as garbage tensors.

use crate::model::BlockTensors;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 7] = b"CUBIC1\n";
/// v2 added the version field itself, per-tensor checksums, and the
/// temp-file-then-rename write protocol.
const VERSION: u32 = 2;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// FNV-1a folded over `bytes`, continuing from `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Serialize a named tensor set (temp file + atomic rename).
pub fn write_tensors(path: &Path, tensors: &[(String, &Tensor)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for (name, t) in tensors {
            if t.is_phantom() {
                bail!("cannot checkpoint phantom tensor {name:?}");
            }
            let nb = name.as_bytes();
            let mut sum = fnv1a(FNV_OFFSET, nb);
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                let db = (d as u64).to_le_bytes();
                sum = fnv1a(sum, &db);
                f.write_all(&db)?;
            }
            for &v in t.data() {
                let vb = v.to_le_bytes();
                sum = fnv1a(sum, &vb);
                f.write_all(&vb)?;
            }
            f.write_all(&sum.to_le_bytes())?;
        }
        f.flush()?;
    }
    // Same-directory rename: atomic publish. A crash before this line
    // leaves at most a stale .tmp; the previous checkpoint survives.
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {}", path.display()))
}

/// Deserialize a named tensor set, verifying version and per-tensor
/// checksums. Truncated or bit-flipped files are rejected with a typed
/// error naming the offending tensor.
pub fn read_tensors(path: &Path) -> Result<HashMap<String, Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 7];
    f.read_exact(&mut magic)
        .with_context(|| format!("{}: truncated checkpoint (no magic)", path.display()))?;
    if &magic != MAGIC {
        bail!("{}: not a cubic checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u32b)
        .with_context(|| format!("{}: truncated checkpoint (no version)", path.display()))?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("{}: unsupported checkpoint version {version} (want {VERSION})", path.display());
    }
    f.read_exact(&mut u32b)
        .with_context(|| format!("{}: truncated checkpoint (no tensor count)", path.display()))?;
    let count = u32::from_le_bytes(u32b) as usize;
    if count > 1_000_000 {
        bail!("corrupt checkpoint: implausible tensor count {count}");
    }
    let mut out = HashMap::with_capacity(count);
    for i in 0..count {
        let trunc = |what: &str| format!("{}: truncated in tensor {i} ({what})", path.display());
        f.read_exact(&mut u32b).with_context(|| trunc("name length"))?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut nb = vec![0u8; name_len];
        f.read_exact(&mut nb).with_context(|| trunc("name"))?;
        let mut sum = fnv1a(FNV_OFFSET, &nb);
        let name = String::from_utf8(nb).map_err(|_| anyhow!("non-utf8 tensor name"))?;
        f.read_exact(&mut u32b).with_context(|| trunc("ndims"))?;
        let ndims = u32::from_le_bytes(u32b) as usize;
        if ndims > 8 {
            bail!("corrupt checkpoint: ndims {ndims}");
        }
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            f.read_exact(&mut u64b).with_context(|| trunc("dims"))?;
            sum = fnv1a(sum, &u64b);
            shape.push(u64::from_le_bytes(u64b) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut buf = [0u8; 4];
        for v in data.iter_mut() {
            f.read_exact(&mut buf)
                .with_context(|| format!("{}: truncated in tensor {name:?} (data)", path.display()))?;
            sum = fnv1a(sum, &buf);
            *v = f32::from_le_bytes(buf);
        }
        f.read_exact(&mut u64b)
            .with_context(|| format!("{}: truncated in tensor {name:?} (checksum)", path.display()))?;
        let stored = u64::from_le_bytes(u64b);
        if stored != sum {
            bail!(
                "{}: checksum mismatch in tensor {name:?} (stored {stored:#018x}, computed \
                 {sum:#018x}) — corrupt checkpoint",
                path.display()
            );
        }
        if out.insert(name.clone(), Tensor::from_vec(&shape, data)).is_some() {
            bail!("duplicate tensor {name:?} in checkpoint");
        }
    }
    Ok(out)
}

fn block_names(layer: usize) -> [(&'static str, String); 12] {
    let n = |s: &str| format!("block{layer}.{s}");
    [
        ("ln1_g", n("ln1_g")), ("ln1_b", n("ln1_b")),
        ("w_qkv", n("w_qkv")), ("b_qkv", n("b_qkv")),
        ("w_proj", n("w_proj")), ("b_proj", n("b_proj")),
        ("ln2_g", n("ln2_g")), ("ln2_b", n("ln2_b")),
        ("w_fc1", n("w_fc1")), ("b_fc1", n("b_fc1")),
        ("w_fc2", n("w_fc2")), ("b_fc2", n("b_fc2")),
    ]
}

/// Save this rank's model shards.
pub fn save_rank(
    dir: &Path,
    rank: usize,
    blocks: &[BlockTensors],
    extra: &[(String, &Tensor)],
) -> Result<()> {
    let mut tensors: Vec<(String, &Tensor)> = Vec::new();
    for (l, b) in blocks.iter().enumerate() {
        let names = block_names(l);
        let fields: [(&str, Option<&Tensor>); 12] = [
            ("ln1_g", b.ln1_g.as_ref()), ("ln1_b", b.ln1_b.as_ref()),
            ("w_qkv", Some(&b.w_qkv)), ("b_qkv", b.b_qkv.as_ref()),
            ("w_proj", Some(&b.w_proj)), ("b_proj", b.b_proj.as_ref()),
            ("ln2_g", b.ln2_g.as_ref()), ("ln2_b", b.ln2_b.as_ref()),
            ("w_fc1", Some(&b.w_fc1)), ("b_fc1", b.b_fc1.as_ref()),
            ("w_fc2", Some(&b.w_fc2)), ("b_fc2", b.b_fc2.as_ref()),
        ];
        for ((key, qual), (key2, t)) in names.iter().zip(fields.iter()) {
            debug_assert_eq!(key, key2);
            if let Some(t) = t {
                tensors.push((qual.clone(), t));
            }
        }
    }
    for (name, t) in extra {
        tensors.push((name.clone(), t));
    }
    write_tensors(&dir.join(format!("rank-{rank}.bin")), &tensors)
}

/// Load this rank's shards back into `blocks` (shapes and ownership must
/// match — i.e. same model config, parallelism and topology as at save).
pub fn load_rank(dir: &Path, rank: usize, blocks: &mut [BlockTensors]) -> Result<()> {
    let map = read_tensors(&dir.join(format!("rank-{rank}.bin")))?;
    for (l, b) in blocks.iter_mut().enumerate() {
        let names = block_names(l);
        let mut set = |key: &str, slot: &mut Tensor| -> Result<()> {
            let qual = &names.iter().find(|(k, _)| *k == key).unwrap().1;
            let t = map
                .get(qual)
                .ok_or_else(|| anyhow!("checkpoint missing {qual}"))?;
            if t.shape() != slot.shape() {
                bail!("{qual}: shape {:?} != expected {:?}", t.shape(), slot.shape());
            }
            *slot = t.clone();
            Ok(())
        };
        set("w_qkv", &mut b.w_qkv)?;
        set("w_proj", &mut b.w_proj)?;
        set("w_fc1", &mut b.w_fc1)?;
        set("w_fc2", &mut b.w_fc2)?;
        let mut set_opt = |key: &str, slot: &mut Option<Tensor>| -> Result<()> {
            let qual = &names.iter().find(|(k, _)| *k == key).unwrap().1;
            match (map.get(qual), slot.as_mut()) {
                (Some(t), Some(s)) => {
                    if t.shape() != s.shape() {
                        bail!("{qual}: shape mismatch");
                    }
                    *s = t.clone();
                    Ok(())
                }
                (None, None) => Ok(()),
                (Some(_), None) => bail!("{qual}: checkpoint has a shard this rank does not own"),
                (None, Some(_)) => bail!("{qual}: rank owns a shard missing from the checkpoint"),
            }
        };
        set_opt("ln1_g", &mut b.ln1_g)?;
        set_opt("ln1_b", &mut b.ln1_b)?;
        set_opt("b_qkv", &mut b.b_qkv)?;
        set_opt("b_proj", &mut b.b_proj)?;
        set_opt("ln2_g", &mut b.ln2_g)?;
        set_opt("ln2_b", &mut b.ln2_b)?;
        set_opt("b_fc1", &mut b.b_fc1)?;
        set_opt("b_fc2", &mut b.b_fc2)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::{init_dense_blocks, ParEnv};
    use crate::rng::Xoshiro256;
    use crate::topology::Parallelism;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("cubic-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn tensor_io_round_trip() {
        let dir = tmpdir("io");
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[7], 1.0, &mut rng);
        let path = dir.join("x.bin");
        write_tensors(&path, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["a"], a);
        assert_eq!(back["b"], b);
    }

    #[test]
    fn corrupt_files_are_rejected() {
        let dir = tmpdir("bad");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(read_tensors(&path).is_err());
        // Valid magic, implausible version word: rejected as unsupported.
        std::fs::write(&path, b"CUBIC1\n\xff\xff\xff\xff").unwrap();
        assert!(read_tensors(&path).is_err());
    }

    #[test]
    fn truncated_files_are_rejected_with_context() {
        let dir = tmpdir("trunc");
        let path = dir.join("t.bin");
        let a = Tensor::full(&[4, 4], 1.5);
        write_tensors(&path, &[("a".into(), &a)]).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Cut mid-data and mid-header: every prefix must fail loudly, not
        // yield a silently short tensor.
        for cut in [full.len() - 9, full.len() / 2, 9] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = format!("{:#}", read_tensors(&path).unwrap_err());
            assert!(err.contains("truncated"), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn bit_flips_fail_the_checksum() {
        let dir = tmpdir("flip");
        let path = dir.join("f.bin");
        let a = Tensor::full(&[8], 2.0);
        write_tensors(&path, &[("a".into(), &a)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit in the middle of the f32 payload region.
        let mid = bytes.len() - 8 - 16; // inside data, before the checksum
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", read_tensors(&path).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn writes_publish_atomically_without_leftover_tmp() {
        let dir = tmpdir("atomic");
        let path = dir.join("x.bin");
        let a = Tensor::full(&[2], 1.0);
        write_tensors(&path, &[("a".into(), &a)]).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists(), "temp file must be renamed away");
        // Overwrite in place: the old file stays readable throughout and
        // the new content wins.
        let b = Tensor::full(&[2], 9.0);
        write_tensors(&path, &[("a".into(), &b)]).unwrap();
        assert_eq!(read_tensors(&path).unwrap()["a"], b);
    }

    #[test]
    fn sharded_save_load_round_trip_3d() {
        let dir = tmpdir("3d");
        let cfg = ModelConfig::tiny();
        let dense = init_dense_blocks(&cfg, 5);
        for rank in 0..8 {
            let env = ParEnv::new(Parallelism::ThreeD, 2, rank);
            let blocks = env.shard_blocks(&dense);
            save_rank(&dir, rank, &blocks, &[]).unwrap();
        }
        // Load into freshly re-inited (different-seed) shards; must equal
        // the original shards afterwards.
        for rank in 0..8 {
            let env = ParEnv::new(Parallelism::ThreeD, 2, rank);
            let want = env.shard_blocks(&dense);
            let other = init_dense_blocks(&cfg, 99);
            let mut got = env.shard_blocks(&other);
            load_rank(&dir, rank, &mut got).unwrap();
            for (g, w) in got.iter().zip(want.iter()) {
                assert_eq!(g.w_qkv, w.w_qkv);
                assert_eq!(g.b_qkv, w.b_qkv);
                assert_eq!(g.ln1_g, w.ln1_g);
                assert_eq!(g.w_fc2, w.w_fc2);
            }
        }
    }

    #[test]
    fn topology_mismatch_is_detected() {
        let dir = tmpdir("mismatch");
        let cfg = ModelConfig::tiny();
        let dense = init_dense_blocks(&cfg, 5);
        let env = ParEnv::new(Parallelism::ThreeD, 2, 0);
        let blocks = env.shard_blocks(&dense);
        save_rank(&dir, 0, &blocks, &[]).unwrap();
        // Loading rank 0's 3-D shards into a Seq model must fail on shape.
        let env_seq = ParEnv::new(Parallelism::Seq, 1, 0);
        let mut seq_blocks = env_seq.shard_blocks(&dense);
        assert!(load_rank(&dir, 0, &mut seq_blocks).is_err());
    }
}
