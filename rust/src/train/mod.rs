//! Training loop: synthetic corpus, embedding/head boundary layers, and the
//! per-rank trainer the engine drives.
//!
//! Scope note (matches the paper §3.2: "we do not discuss the embedding and
//! output layers"): the tensor-parallel region is the transformer core; the
//! embedding lookup and LM head run *replicated* — every rank computes them
//! identically from the same tokens and applies identical updates, which
//! keeps replicas consistent without any extra communication. The paper's
//! benchmarks (and ours) time the core only.

pub mod checkpoint;

use crate::collectives::all_gather_into;
use crate::comm::fault::{catch_comm, CommError};
use crate::comm::Endpoint;
use crate::config::{CubicConfig, ModelConfig};
use crate::model::{core_bwd, core_fwd, BlockTensors, ParEnv};
use crate::ops;
use crate::optim::{lr_at, Optimizer};
use crate::parallel::hybrid::Hybrid;
use crate::parallel::pipeline::{pipeline_core_step, Pipeline};
use crate::rng::{Xoshiro256, Zipf};
use crate::tensor::Tensor;
use crate::topology::Parallelism;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// Exact integer → tensor encoding for checkpoint/donation metadata: the
/// two 32-bit halves of the value travel bit-for-bit as f32 payloads
/// (`from_bits`), so counters above 2^24 — where an `as f32` cast starts
/// rounding to even — survive the round-trip exactly. Safe because every
/// consumer (the checkpoint serializer, the virtual transport) copies raw
/// lane bytes and never does arithmetic on them.
pub fn encode_u64(v: u64) -> Tensor {
    Tensor::from_vec(&[2], vec![f32::from_bits(v as u32), f32::from_bits((v >> 32) as u32)])
}

/// Inverse of [`encode_u64`], looked up by `key` with typed errors: a
/// missing tensor and a wrong-arity tensor (including the empty tensor a
/// truncation bug could produce) both name the offending key instead of
/// panicking on an out-of-bounds index.
pub fn decode_u64(map: &HashMap<String, Tensor>, key: &str) -> Result<u64> {
    let t = map.get(key).ok_or_else(|| anyhow!("checkpoint missing {key}"))?;
    let d = t.data();
    if d.len() != 2 {
        bail!(
            "checkpoint tensor {key}: expected 2 bit-half lanes, got {} — corrupt metadata",
            d.len()
        );
    }
    Ok(d[0].to_bits() as u64 | (d[1].to_bits() as u64) << 32)
}

/// Synthetic char-level corpus with learnable structure: a fixed random
/// first-order Markov chain over the vocabulary (Zipfian stationary flavor).
/// A model that learns the transition table reaches the chain's conditional
/// entropy; the falling loss curve in EXPERIMENTS.md is real learning.
pub struct MarkovCorpus {
    vocab: usize,
    /// transition[v] = the 4 candidate successors of token v.
    successors: Vec<[usize; 4]>,
    rng: Xoshiro256,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> MarkovCorpus {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xC0FFEE);
        let zipf = Zipf::new(vocab, 1.2);
        let successors = (0..vocab)
            .map(|_| {
                [
                    zipf.sample(&mut rng),
                    zipf.sample(&mut rng),
                    zipf.sample(&mut rng),
                    zipf.sample(&mut rng),
                ]
            })
            .collect();
        MarkovCorpus { vocab, successors, rng }
    }

    /// Sample a batch for `step`: `(inputs, targets)`, each `batch·seq`
    /// token ids, targets shifted by one. Deterministic in (seed, step)
    /// and independent of rank — every rank regenerates the same batch.
    pub fn batch(&self, batch: usize, seq: usize, step: u64) -> (Vec<usize>, Vec<usize>) {
        let mut rng = self.rng.split(step);
        let mut inputs = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut tok = rng.next_below(self.vocab as u64) as usize;
            for _ in 0..seq {
                inputs.push(tok);
                // 90% follow the chain, 10% noise.
                let next = if rng.next_f32() < 0.9 {
                    self.successors[tok][rng.next_below(4) as usize]
                } else {
                    rng.next_below(self.vocab as u64) as usize
                };
                targets.push(next);
                tok = next;
            }
        }
        (inputs, targets)
    }
}

/// Token + position embedding (replicated).
pub struct Embedding {
    pub table: Tensor, // (vocab, h)
    pub pos: Tensor,   // (seq, h)
}

impl Embedding {
    pub fn init(cfg: &ModelConfig, rng: &mut Xoshiro256) -> Embedding {
        Embedding {
            table: Tensor::randn(&[cfg.vocab, cfg.hidden], 0.02, rng),
            pos: Tensor::randn(&[cfg.seq, cfg.hidden], 0.01, rng),
        }
    }

    /// X[r] = table[tokens[r]] + pos[r mod seq].
    pub fn fwd(&self, tokens: &[usize], seq: usize) -> Tensor {
        let h = self.table.dims2().1;
        let rows = tokens.len();
        let mut out = vec![0.0f32; rows * h];
        let td = self.table.data();
        let pd = self.pos.data();
        for (r, &t) in tokens.iter().enumerate() {
            let p = r % seq;
            for c in 0..h {
                out[r * h + c] = td[t * h + c] + pd[p * h + c];
            }
        }
        Tensor::from_vec(&[rows, h], out)
    }

    /// Accumulate gradients; returns `(d_table, d_pos)`.
    pub fn bwd(&self, tokens: &[usize], seq: usize, dx: &Tensor) -> (Tensor, Tensor) {
        let (rows, h) = dx.dims2();
        assert_eq!(rows, tokens.len());
        let mut dt = Tensor::zeros(self.table.shape());
        let mut dp = Tensor::zeros(self.pos.shape());
        let dxd = dx.data();
        {
            let dtd = dt.data_mut();
            for (r, &t) in tokens.iter().enumerate() {
                for c in 0..h {
                    dtd[t * h + c] += dxd[r * h + c];
                }
            }
        }
        {
            let dpd = dp.data_mut();
            for r in 0..rows {
                let p = r % seq;
                for c in 0..h {
                    dpd[p * h + c] += dxd[r * h + c];
                }
            }
        }
        (dt, dp)
    }
}

/// Final layernorm + LM head (replicated).
pub struct Head {
    pub ln_g: Tensor,
    pub ln_b: Tensor,
    pub w: Tensor, // (h, vocab)
    pub b: Tensor, // (vocab)
}

pub struct HeadCache {
    xhat: Tensor,
    istd: Tensor,
    ln_out: Tensor,
}

impl Head {
    pub fn init(cfg: &ModelConfig, rng: &mut Xoshiro256) -> Head {
        Head {
            ln_g: Tensor::ones(&[cfg.hidden]),
            ln_b: Tensor::zeros(&[cfg.hidden]),
            w: Tensor::randn(&[cfg.hidden, cfg.vocab], 0.02, rng),
            b: Tensor::zeros(&[cfg.vocab]),
        }
    }

    /// Returns `(loss, dX, grads)` fused: logits never leave this function.
    pub fn loss_and_grads(
        &self,
        x: &Tensor,
        targets: &[usize],
        eps: f32,
    ) -> (f32, Tensor, HeadGrads) {
        let (y, xhat, istd) = crate::model::local_layernorm(x, &self.ln_g, &self.ln_b, eps);
        let cache = HeadCache { xhat, istd, ln_out: y };
        let logits = cache.ln_out.matmul(&self.w).add_row_vector(&self.b);
        let (loss, dlogits) = ops::cross_entropy(&logits, targets);
        let d_ln = dlogits.matmul_nt(&self.w);
        let dw = cache.ln_out.matmul_tn(&dlogits);
        let db = dlogits.sum_rows();
        let (dx, dg, dbeta) = crate::model::local_layernorm_backward(
            &d_ln, &cache.xhat, &cache.istd, &self.ln_g,
        );
        (loss, dx, HeadGrads { ln_g: dg, ln_b: dbeta, w: dw, b: db })
    }
}

pub struct HeadGrads {
    pub ln_g: Tensor,
    pub ln_b: Tensor,
    pub w: Tensor,
    pub b: Tensor,
}

/// Per-rank training state.
pub struct TrainerRank {
    pub env: ParEnv,
    pub rank: usize,
    pub blocks: Vec<BlockTensors>,
    pub emb: Embedding,
    pub head: Head,
    opt_core: Optimizer,
    opt_emb: Optimizer,
    /// ZeRO (stage ≥ 1) only: this rank's replica group, ordered by replica
    /// index — the group the updated weight slices are all-gathered over
    /// after each optimizer step. `None` when ZeRO is off (replicated
    /// optimizer, no post-step gather).
    zero_group: Option<Vec<usize>>,
    corpus: MarkovCorpus,
    cfg: CubicConfig,
}

/// What each rank reports back after training.
#[derive(Clone, Debug)]
pub struct RankReport {
    pub losses: Vec<f32>,
    pub step_virtual_times: Vec<f64>,
}

/// One rank's result from a supervised generation (see
/// [`TrainerRank::run_supervised`]). `losses`/`step_virtual_times` are
/// *absolute* — the prefix carried into the generation plus everything
/// completed in it — so the supervisor never has to stitch segments.
pub struct RankOutcome {
    /// The trainer state, valid at the end of the last fully completed
    /// step. `None` when this rank crashed (a dead process loses its
    /// memory — recovery must come from a checkpoint or a donor replica).
    pub trainer: Option<Box<TrainerRank>>,
    /// Reached the final step without a comm failure.
    pub completed: bool,
    pub losses: Vec<f32>,
    pub step_virtual_times: Vec<f64>,
    /// The typed failure, when `completed` is false.
    pub error: Option<CommError>,
}

/// Base tag for the replica-donation tensor stream. Bit 63 keeps it
/// outside the collective tag space; donation runs at a quiescent point
/// (no collectives in flight), so sequential tags from here are unique.
const DONATE_TAG: u64 = 0xD0A7_0000_0000_0000;

impl TrainerRank {
    pub fn new(cfg: &CubicConfig, rank: usize) -> TrainerRank {
        // ZeRO (stage 1/2): swap the hybrid leaf's grad all-reduce for
        // reduce-scatter and remember the replica group for the post-step
        // weight all-gather. Config validation guarantees zero_stage > 0
        // only appears with top-level Hybrid parallelism.
        let zero = (cfg.zero_stage >= 1).then(|| {
            let Parallelism::Hybrid { replicas, inner } = cfg.parallelism else {
                panic!("zero_stage {} requires Hybrid parallelism", cfg.zero_stage)
            };
            let iw = inner.as_parallelism().world_size(cfg.edge);
            let group: Vec<usize> = (0..replicas).map(|k| k * iw + rank % iw).collect();
            (replicas, rank / iw, group, inner)
        });
        let env = match &zero {
            Some((replicas, _, _, inner)) => ParEnv::from_ops(Box::new(
                Hybrid::for_kind(*replicas, *inner, cfg.edge, rank)
                    .with_zero_stage(cfg.zero_stage),
            )),
            None => ParEnv::new(cfg.parallelism, cfg.edge, rank),
        };
        let dense = crate::model::init_dense_blocks(&cfg.model, cfg.train.seed);
        // Pipelined ranks hold only their stage's contiguous layer slice
        // (sharded by the inner mesh); everyone else holds every layer.
        // The full stack is initialised either way so layer `l`'s weights
        // are identical across topologies (the parity pin depends on it).
        let blocks = match cfg.parallelism {
            Parallelism::Pipeline { stages, micro_batches, inner } => {
                let pipe = Pipeline::for_kind(stages, micro_batches, inner, cfg.edge, rank);
                let range = pipe.layer_range(cfg.model.layers);
                dense[range].iter().map(|b| env.ops().shard_block(b)).collect()
            }
            _ => env.shard_blocks(&dense),
        };
        // Boundary layers: identical init on every rank.
        let mut brng = Xoshiro256::seed_from_u64(cfg.train.seed ^ 0xB0DA0);
        let emb = Embedding::init(&cfg.model, &mut brng);
        let head = Head::init(&cfg.model, &mut brng);
        // Optimizer state shapes: core pairs first, then emb/head. The
        // block clone is refcount bumps only under the Arc-backed storage —
        // nothing is copied to enumerate shapes.
        let mut shapes: Vec<Vec<usize>> = Vec::new();
        {
            let mut tmp = blocks.clone();
            for (b, g) in tmp.iter_mut().zip(blocks.iter()) {
                for (p, _) in b.pairs_mut(g) {
                    shapes.push(p.shape().to_vec());
                }
            }
        }
        let opt_core = match &zero {
            Some((replicas, replica, _, _)) => {
                Optimizer::new_partitioned(&cfg.train, &shapes, *replicas, *replica)
            }
            None => Optimizer::new(&cfg.train, &shapes),
        };
        let emb_shapes = vec![
            emb.table.shape().to_vec(),
            emb.pos.shape().to_vec(),
            head.ln_g.shape().to_vec(),
            head.ln_b.shape().to_vec(),
            head.w.shape().to_vec(),
            head.b.shape().to_vec(),
        ];
        let opt_emb = Optimizer::new(&cfg.train, &emb_shapes);
        let corpus = MarkovCorpus::new(cfg.model.vocab, cfg.train.seed);
        TrainerRank {
            env,
            rank,
            blocks,
            emb,
            head,
            opt_core,
            opt_emb,
            zero_group: zero.map(|(_, _, group, _)| group),
            corpus,
            cfg: cfg.clone(),
        }
    }

    /// One full training step; returns the loss.
    pub fn step(&mut self, ep: &mut Endpoint, step: usize) -> f32 {
        if matches!(self.cfg.parallelism, Parallelism::Pipeline { .. }) {
            return self.step_pipelined(ep, step);
        }
        let m = &self.cfg.model;
        let rows = m.batch * m.seq;
        let (tokens, targets) = self.corpus.batch(m.batch, m.seq, step as u64);

        // Boundary: replicated embedding.
        let x_global = self.emb.fwd(&tokens, m.seq);
        let x_local = self.env.scatter_activation(ep, &x_global);

        // Distributed core.
        let (y_local, caches) = core_fwd(ep, self.env.ops(), &self.blocks, &x_local, m);
        let y_global = self.env.gather_activation(ep, &y_local, rows, m.hidden);

        // Boundary: replicated head + loss (identical on all ranks).
        let (loss, dy_global, head_grads) =
            self.head.loss_and_grads(&y_global, &targets, m.eps);

        // Distributed backward.
        let dy_local = self.env.scatter_activation(ep, &dy_global);
        let (dx_local, block_grads) =
            core_bwd(ep, self.env.ops(), &self.blocks, &caches, &dy_local, m);

        // Boundary backward: embedding grads from the gathered dx.
        let dx_global = self.env.gather_activation(ep, &dx_local, rows, m.hidden);
        let (d_table, d_pos) = self.emb.bwd(&tokens, m.seq, &dx_global);

        // Optimizer boundary: every in-flight deferred grad sync must have
        // landed on the compute clock before the update is applied (the
        // gradients themselves are already valid — tickets are clock-only).
        ep.join_all();

        self.apply_update(ep, step, &block_grads, &d_table, &d_pos, &head_grads);
        loss
    }

    /// One pipelined training step: same boundary layers and optimizer as
    /// [`TrainerRank::step`], with the core driven by
    /// [`pipeline_core_step`] over this rank's stage slice. The head/loss
    /// runs replicated on every rank from the relayed full output, so the
    /// returned loss — and the boundary-layer updates — are bit-identical
    /// across ranks, exactly as in the unpipelined path.
    fn step_pipelined(&mut self, ep: &mut Endpoint, step: usize) -> f32 {
        let Parallelism::Pipeline { stages, micro_batches, inner } = self.cfg.parallelism else {
            unreachable!("step_pipelined outside a pipeline config");
        };
        let pipe = Pipeline::for_kind(stages, micro_batches, inner, self.cfg.edge, self.rank);
        let m = &self.cfg.model;
        let (tokens, targets) = self.corpus.batch(m.batch, m.seq, step as u64);

        let x_global = self.emb.fwd(&tokens, m.seq);
        let head = &self.head;
        let eps = m.eps;
        let mut loss = 0.0f32;
        let mut head_grads: Option<HeadGrads> = None;
        let out = pipeline_core_step(ep, &pipe, &self.blocks, &x_global, m, &mut |_ep, y_full| {
            let (l, dy, hg) = head.loss_and_grads(y_full, &targets, eps);
            loss = l;
            head_grads = Some(hg);
            dy
        });
        let head_grads = head_grads.expect("pipeline head closure runs exactly once");

        // Boundary backward from the relayed full embedding gradient.
        let (d_table, d_pos) = self.emb.bwd(&tokens, m.seq, &out.dx_full);

        ep.join_all();
        self.apply_update(ep, step, &out.grads, &d_table, &d_pos, &head_grads);
        loss
    }

    /// The optimizer tail shared by the plain and pipelined steps.
    ///
    /// Under ZeRO (`zero_group` set) the core gradients arriving here are
    /// this replica's reduce-scattered `ceil(n/r)` chunks, the optimizer
    /// updates only the owned weight slice, and the updated slices are
    /// all-gathered back over the replica group as deferred collectives —
    /// the weights are bitwise complete immediately (data moves eagerly),
    /// while the gather's clock cost overlaps the next step's compute and
    /// is retired by its `join_all`.
    fn apply_update(
        &mut self,
        ep: &mut Endpoint,
        step: usize,
        block_grads: &[BlockTensors],
        d_table: &Tensor,
        d_pos: &Tensor,
        head_grads: &HeadGrads,
    ) {
        let lr = lr_at(&self.cfg.train, step);
        let mut pairs: Vec<(&mut Tensor, &Tensor)> = Vec::new();
        for (b, g) in self.blocks.iter_mut().zip(block_grads.iter()) {
            pairs.extend(b.pairs_mut(g));
        }
        self.opt_core.step(&mut pairs, lr);
        if let Some(group) = &self.zero_group {
            // Rebuild each full parameter from the per-replica updated
            // slices. Group order is replica order is partition order, so
            // replica j's chunk lands at flat offset j·padded — exactly the
            // span its optimizer updated. Our own chunk round-trips as a
            // bitwise copy.
            let parts = self.opt_core.partition().expect("ZeRO trainer has a partitioned optimizer");
            for (k, (p, _)) in pairs.iter_mut().enumerate() {
                if p.is_phantom() {
                    continue;
                }
                let part = parts[k];
                let mut mine = vec![0.0f32; part.padded];
                mine[..part.len]
                    .copy_from_slice(&p.data()[part.offset..part.offset + part.len]);
                let mine = Tensor::from_vec(&[part.padded], mine);
                let _ = ep.defer(|ep| all_gather_into(ep, group, mine, p.data_mut()));
            }
        }
        let mut bpairs: Vec<(&mut Tensor, &Tensor)> = vec![
            (&mut self.emb.table, d_table),
            (&mut self.emb.pos, d_pos),
            (&mut self.head.ln_g, &head_grads.ln_g),
            (&mut self.head.ln_b, &head_grads.ln_b),
            (&mut self.head.w, &head_grads.w),
            (&mut self.head.b, &head_grads.b),
        ];
        self.opt_emb.step(&mut bpairs, lr);
    }

    /// Run the configured number of steps.
    pub fn run(&mut self, ep: &mut Endpoint) -> RankReport {
        let steps = self.cfg.train.steps;
        let mut losses = Vec::with_capacity(steps);
        let mut vts = Vec::with_capacity(steps);
        for s in 0..steps {
            let t0 = ep.clock;
            let loss = self.step(ep, s);
            losses.push(loss);
            vts.push(ep.clock - t0);
        }
        RankReport { losses, step_virtual_times: vts }
    }

    /// Run steps `[start, end)` under fault supervision: every step is a
    /// `catch_comm` boundary, so an injected crash, a dead peer, or an
    /// exhausted retry surfaces as a clean [`RankOutcome`] instead of a
    /// hang or a dead thread. Checkpoints are written every `ckpt_every`
    /// completed steps (and at the end) when `dir` is given.
    ///
    /// Why the trainer stays valid on failure: every step's communication
    /// — the world-connected activation gathers and grad syncs — precedes
    /// the optimizer update (`step` joins all tickets first), so an abort
    /// anywhere in step `S` leaves the weights and optimizer exactly at
    /// the state after step `S − 1`, on every surviving rank.
    pub fn run_supervised(
        mut self: Box<Self>,
        ep: &mut Endpoint,
        start: usize,
        end: usize,
        ckpt_every: usize,
        dir: Option<&Path>,
        mut losses: Vec<f32>,
        mut step_virtual_times: Vec<f64>,
    ) -> RankOutcome {
        assert_eq!(losses.len(), start, "carried losses must cover exactly [0, start)");
        for s in start..end {
            let t0 = ep.clock;
            let res = catch_comm(|| {
                ep.maybe_crash(s);
                self.step(ep, s)
            });
            match res {
                Ok(loss) => {
                    losses.push(loss);
                    step_virtual_times.push(ep.clock - t0);
                }
                Err(e) => {
                    // A crashed rank simulates a dead process: its memory
                    // is gone. Survivors keep their (still valid) state.
                    let trainer = match e {
                        CommError::Crashed { .. } => None,
                        _ => Some(self),
                    };
                    return RankOutcome {
                        trainer,
                        completed: false,
                        losses,
                        step_virtual_times,
                        error: Some(e),
                    };
                }
            }
            if let Some(dir) = dir {
                if ckpt_every > 0 && (s + 1) % ckpt_every == 0 && s + 1 < end {
                    if let Err(e) = self.save_checkpoint(dir, s + 1, &losses) {
                        return Self::save_failed(self, e, losses, step_virtual_times);
                    }
                }
            }
        }
        if let Some(dir) = dir {
            if let Err(e) = self.save_checkpoint(dir, end, &losses) {
                return Self::save_failed(self, e, losses, step_virtual_times);
            }
        }
        RankOutcome {
            trainer: Some(self),
            completed: true,
            losses,
            step_virtual_times,
            error: None,
        }
    }

    /// A checkpoint save hit an IO error. The write protocol is
    /// temp-file-then-rename, so the failed save published nothing and the
    /// trainer state is still valid — surface a typed, retryable outcome
    /// instead of panicking the rank thread.
    fn save_failed(
        trainer: Box<Self>,
        err: anyhow::Error,
        losses: Vec<f32>,
        step_virtual_times: Vec<f64>,
    ) -> RankOutcome {
        let rank = trainer.rank;
        RankOutcome {
            trainer: Some(trainer),
            completed: false,
            losses,
            step_virtual_times,
            error: Some(CommError::Checkpoint { rank, msg: format!("{err:#}") }),
        }
    }

    /// Persist this rank's full training state (model shards, optimizer
    /// state, progress) as one crash-consistent file. Replicated state
    /// (embedding, head, their optimizer, the loss history) is stored only
    /// in rank 0's file; every rank reads it back from there.
    pub fn save_checkpoint(&self, dir: &Path, steps_done: usize, losses: &[f32]) -> Result<()> {
        let core_state = self.opt_core.state_tensors();
        // Progress counters are stored exactly ([`encode_u64`]): an
        // `as f32` cast would silently round them past 2^24 steps.
        let core_t = encode_u64(self.opt_core.timestep());
        let emb_t = encode_u64(self.opt_emb.timestep());
        let steps_t = encode_u64(steps_done as u64);
        // Only rank 0 persists the loss history, and only when non-empty —
        // the loader treats absence as "no history yet".
        let losses_t = (self.rank == 0 && !losses.is_empty())
            .then(|| Tensor::from_vec(&[losses.len()], losses.to_vec()));
        let mut extra: Vec<(String, &Tensor)> = Vec::new();
        for (i, t) in core_state.iter().enumerate() {
            extra.push((format!("opt.core.{i}"), t));
        }
        extra.push(("opt.core.t".into(), &core_t));
        extra.push(("meta.steps_done".into(), &steps_t));
        let emb_state = self.opt_emb.state_tensors();
        if self.rank == 0 {
            extra.push(("emb.table".into(), &self.emb.table));
            extra.push(("emb.pos".into(), &self.emb.pos));
            extra.push(("head.ln_g".into(), &self.head.ln_g));
            extra.push(("head.ln_b".into(), &self.head.ln_b));
            extra.push(("head.w".into(), &self.head.w));
            extra.push(("head.b".into(), &self.head.b));
            for (i, t) in emb_state.iter().enumerate() {
                extra.push((format!("opt.emb.{i}"), t));
            }
            extra.push(("opt.emb.t".into(), &emb_t));
            if let Some(lt) = &losses_t {
                extra.push(("meta.losses".into(), lt));
            }
        }
        checkpoint::save_rank(dir, self.rank, &self.blocks, &extra)
    }

    /// Rebuild a rank's trainer from the last checkpoint. Returns the
    /// trainer plus `(steps_done, losses)` so the supervisor knows where
    /// to resume. Fails (typed) on missing files, truncation, corruption,
    /// or shards disagreeing about the step.
    pub fn load_checkpoint(
        cfg: &CubicConfig,
        rank: usize,
        dir: &Path,
    ) -> Result<(Box<TrainerRank>, usize, Vec<f32>)> {
        let assign = |map: &HashMap<String, Tensor>,
                      key: &str,
                      slot: &mut Tensor|
         -> Result<()> {
            let t = map.get(key).ok_or_else(|| anyhow!("checkpoint missing {key}"))?;
            if t.shape() != slot.shape() {
                bail!("{key}: shape {:?} != expected {:?}", t.shape(), slot.shape());
            }
            *slot = t.clone();
            Ok(())
        };
        let mut tr = Box::new(TrainerRank::new(cfg, rank));
        checkpoint::load_rank(dir, rank, &mut tr.blocks)?;
        let own = checkpoint::read_tensors(&dir.join(format!("rank-{rank}.bin")))?;
        for (i, slot) in tr.opt_core.state_tensors_mut().into_iter().enumerate() {
            assign(&own, &format!("opt.core.{i}"), slot)?;
        }
        tr.opt_core.set_timestep(decode_u64(&own, "opt.core.t")?);
        let steps_done = decode_u64(&own, "meta.steps_done")? as usize;
        let zero = checkpoint::read_tensors(&dir.join("rank-0.bin"))?;
        let steps0 = decode_u64(&zero, "meta.steps_done")? as usize;
        if steps0 != steps_done {
            bail!("checkpoint shards disagree on progress: rank {rank} at {steps_done}, rank 0 at {steps0}");
        }
        assign(&zero, "emb.table", &mut tr.emb.table)?;
        assign(&zero, "emb.pos", &mut tr.emb.pos)?;
        assign(&zero, "head.ln_g", &mut tr.head.ln_g)?;
        assign(&zero, "head.ln_b", &mut tr.head.ln_b)?;
        assign(&zero, "head.w", &mut tr.head.w)?;
        assign(&zero, "head.b", &mut tr.head.b)?;
        for (i, slot) in tr.opt_emb.state_tensors_mut().into_iter().enumerate() {
            assign(&zero, &format!("opt.emb.{i}"), slot)?;
        }
        tr.opt_emb.set_timestep(decode_u64(&zero, "opt.emb.t")?);
        let losses: Vec<f32> = zero
            .get("meta.losses")
            .map(|t| t.data().to_vec())
            .unwrap_or_default();
        if steps_done > 0 && losses.len() != steps_done {
            bail!(
                "checkpoint loss history has {} entries for {steps_done} steps",
                losses.len()
            );
        }
        Ok((tr, steps_done, losses))
    }

    // --- replica donation (Hybrid recovery without disk) ---------------

    /// The donation stream, in a fixed order both sides enumerate
    /// identically: block shards (present fields only), core optimizer
    /// state, boundary layers, boundary optimizer state. Donor and
    /// adoptee occupy the same inner rank of their replicas, so their
    /// shard topology — including which optional fields are present — is
    /// identical by construction.
    fn donation_refs(&self) -> Vec<&Tensor> {
        let mut out: Vec<&Tensor> = Vec::new();
        for b in &self.blocks {
            for t in [&b.ln1_g, &b.ln1_b].into_iter().flatten() {
                out.push(t);
            }
            out.push(&b.w_qkv);
            out.extend(&b.b_qkv);
            out.push(&b.w_proj);
            out.extend(&b.b_proj);
            for t in [&b.ln2_g, &b.ln2_b].into_iter().flatten() {
                out.push(t);
            }
            out.push(&b.w_fc1);
            out.extend(&b.b_fc1);
            out.push(&b.w_fc2);
            out.extend(&b.b_fc2);
        }
        out.extend(self.opt_core.state_tensors());
        out.push(&self.emb.table);
        out.push(&self.emb.pos);
        out.push(&self.head.ln_g);
        out.push(&self.head.ln_b);
        out.push(&self.head.w);
        out.push(&self.head.b);
        out.extend(self.opt_emb.state_tensors());
        out
    }

    /// Mutable mirror of [`TrainerRank::donation_refs`], same order.
    fn donation_slots(&mut self) -> Vec<&mut Tensor> {
        let mut out: Vec<&mut Tensor> = Vec::new();
        for b in &mut self.blocks {
            for t in [&mut b.ln1_g, &mut b.ln1_b].into_iter().flatten() {
                out.push(t);
            }
            out.push(&mut b.w_qkv);
            out.extend(&mut b.b_qkv);
            out.push(&mut b.w_proj);
            out.extend(&mut b.b_proj);
            for t in [&mut b.ln2_g, &mut b.ln2_b].into_iter().flatten() {
                out.push(t);
            }
            out.push(&mut b.w_fc1);
            out.extend(&mut b.b_fc1);
            out.push(&mut b.w_fc2);
            out.extend(&mut b.b_fc2);
        }
        out.extend(self.opt_core.state_tensors_mut());
        out.push(&mut self.emb.table);
        out.push(&mut self.emb.pos);
        out.push(&mut self.head.ln_g);
        out.push(&mut self.head.ln_b);
        out.push(&mut self.head.w);
        out.push(&mut self.head.b);
        out.extend(self.opt_emb.state_tensors_mut());
        out
    }

    /// Donate this rank's full state to `to` over the comm layer (the
    /// Hybrid replica-redundancy path: a surviving replica re-seeds a
    /// restarted rank without touching disk). Clock cost rides the normal
    /// send/recv ledger, so recovery shows up in virtual time.
    pub fn send_donation(&self, ep: &mut Endpoint, to: usize, losses: &[f32]) {
        let mut tag = DONATE_TAG;
        for t in self.donation_refs() {
            ep.send(to, tag, t);
            tag += 1;
        }
        // Timesteps travel as u64 bit-halves (same rationale as the
        // checkpoint metadata — exact past 2^24).
        let meta = Tensor::from_vec(&[4], {
            let mut v = encode_u64(self.opt_core.timestep()).data().to_vec();
            v.extend_from_slice(encode_u64(self.opt_emb.timestep()).data());
            v
        });
        ep.send(to, tag, &meta);
        tag += 1;
        let lt = Tensor::from_vec(&[losses.len().max(1)], {
            let mut v = losses.to_vec();
            if v.is_empty() {
                v.push(f32::NAN);
            }
            v
        });
        ep.send(to, tag, &lt);
    }

    /// Adopt a donated state from `from` (see
    /// [`TrainerRank::send_donation`]); returns the donor's loss history.
    pub fn receive_donation(&mut self, ep: &mut Endpoint, from: usize, steps_done: usize) -> Vec<f32> {
        let mut tag = DONATE_TAG;
        for slot in self.donation_slots() {
            let t = ep.recv(from, tag);
            assert_eq!(t.shape(), slot.shape(), "donated tensor shape mismatch at tag {tag:#x}");
            *slot = t;
            tag += 1;
        }
        let meta = ep.recv(from, tag);
        let md = meta.data();
        assert_eq!(md.len(), 4, "donation meta must carry two u64s as f32 bit-halves");
        self.opt_core.set_timestep(md[0].to_bits() as u64 | (md[1].to_bits() as u64) << 32);
        self.opt_emb.set_timestep(md[2].to_bits() as u64 | (md[3].to_bits() as u64) << 32);
        tag += 1;
        let lt = ep.recv(from, tag);
        let losses: Vec<f32> = if steps_done == 0 {
            Vec::new()
        } else {
            lt.data().to_vec()
        };
        assert_eq!(losses.len(), steps_done, "donated loss history length mismatch");
        losses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_in_range() {
        let c1 = MarkovCorpus::new(50, 9);
        let c2 = MarkovCorpus::new(50, 9);
        let (i1, t1) = c1.batch(4, 8, 3);
        let (i2, t2) = c2.batch(4, 8, 3);
        assert_eq!(i1, i2);
        assert_eq!(t1, t2);
        assert_eq!(i1.len(), 32);
        assert!(i1.iter().all(|&t| t < 50));
        // Different steps differ.
        let (i3, _) = c1.batch(4, 8, 4);
        assert_ne!(i1, i3);
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // The most common successor of a token should dominate: measure the
        // empirical top-successor share; the chain guarantees ≥ ~25%·0.9.
        let c = MarkovCorpus::new(20, 1);
        let mut counts = vec![std::collections::HashMap::new(); 20];
        for step in 0..200u64 {
            let (i, t) = c.batch(2, 16, step);
            for (a, b) in i.iter().zip(t.iter()) {
                *counts[*a].entry(*b).or_insert(0usize) += 1;
            }
        }
        let mut top_share = 0.0;
        let mut total = 0.0;
        for m in &counts {
            let sum: usize = m.values().sum();
            if sum == 0 {
                continue;
            }
            let max = *m.values().max().unwrap();
            top_share += max as f64;
            total += sum as f64;
        }
        assert!(top_share / total > 0.3, "chain not predictive: {}", top_share / total);
    }

    #[test]
    fn embedding_fwd_bwd_consistency() {
        let cfg = ModelConfig::tiny();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let emb = Embedding::init(&cfg, &mut rng);
        let tokens = vec![1usize, 5, 1, 7];
        let x = emb.fwd(&tokens, 2);
        assert_eq!(x.shape(), &[4, cfg.hidden]);
        // Rows with the same token at the same position are identical.
        // tokens[0] and tokens[2] are both token 1 at position 0.
        assert!(x.block(0, 0, 1, cfg.hidden).max_abs_diff(&x.block(2, 0, 1, cfg.hidden)) < 1e-6);
        // bwd: gradient of duplicated token accumulates.
        let dx = Tensor::ones(&[4, cfg.hidden]);
        let (dt, dp) = emb.bwd(&tokens, 2, &dx);
        assert_eq!(dt.at2(1, 0), 2.0); // token 1 appears twice
        assert_eq!(dt.at2(5, 0), 1.0);
        assert_eq!(dt.at2(0, 0), 0.0);
        assert_eq!(dp.at2(0, 0), 2.0); // two rows at position 0
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cubic-train-{tag}-{}", std::process::id()))
    }

    #[test]
    fn u64_metadata_encoding_is_exact() {
        for v in [0u64, 1, (1 << 24) - 1, 1 << 24, (1 << 24) + 1, (1 << 42) + 12345, u64::MAX] {
            let mut map = HashMap::new();
            map.insert("k".to_string(), encode_u64(v));
            assert_eq!(decode_u64(&map, "k").unwrap(), v, "value {v}");
        }
        // The bug this encoding replaces: an `as f32` cast rounds past 2^24.
        assert_ne!(((1u64 << 24) + 1) as f32 as u64, (1 << 24) + 1);
        // Typed errors name the offending key instead of panicking.
        let mut map = HashMap::new();
        let err = decode_u64(&map, "opt.core.t").unwrap_err().to_string();
        assert!(err.contains("opt.core.t"), "{err}");
        map.insert("opt.core.t".to_string(), Tensor::from_vec(&[0], Vec::new()));
        let err = decode_u64(&map, "opt.core.t").unwrap_err().to_string();
        assert!(err.contains("opt.core.t") && err.contains("bit-half"), "{err}");
        map.insert("opt.core.t".to_string(), Tensor::from_vec(&[1], vec![3.0]));
        let err = decode_u64(&map, "opt.core.t").unwrap_err().to_string();
        assert!(err.contains("got 1"), "{err}");
    }

    #[test]
    fn checkpoint_metadata_survives_2p24_steps() {
        // Regression for the f32 counter bug: progress counters above 2^24
        // must round-trip through a checkpoint file exactly.
        let cfg = CubicConfig {
            parallelism: Parallelism::Seq,
            edge: 1,
            ..CubicConfig::default()
        };
        let mut tr = TrainerRank::new(&cfg, 0);
        let big_core = (1u64 << 33) + 7;
        let big_emb = (1u64 << 24) + 1;
        tr.opt_core.set_timestep(big_core);
        tr.opt_emb.set_timestep(big_emb);
        let dir = tmpdir("big-steps");
        let steps_done = (1usize << 24) + 3;
        tr.save_checkpoint(&dir, steps_done, &[]).unwrap();
        let own = checkpoint::read_tensors(&dir.join("rank-0.bin")).unwrap();
        assert_eq!(decode_u64(&own, "opt.core.t").unwrap(), big_core);
        assert_eq!(decode_u64(&own, "opt.emb.t").unwrap(), big_emb);
        assert_eq!(decode_u64(&own, "meta.steps_done").unwrap(), steps_done as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_checkpoint_round_trips_exact_timesteps() {
        let cfg = CubicConfig {
            parallelism: Parallelism::Seq,
            edge: 1,
            ..CubicConfig::default()
        };
        let mut tr = TrainerRank::new(&cfg, 0);
        tr.opt_core.set_timestep((1u64 << 30) + 5);
        tr.opt_emb.set_timestep((1u64 << 24) + 1);
        let dir = tmpdir("load-roundtrip");
        tr.save_checkpoint(&dir, 2, &[1.5, 1.25]).unwrap();
        let (tr2, steps, losses) = TrainerRank::load_checkpoint(&cfg, 0, &dir).unwrap();
        assert_eq!(steps, 2);
        assert_eq!(losses, vec![1.5, 1.25]);
        assert_eq!(tr2.opt_core.timestep(), (1u64 << 30) + 5);
        assert_eq!(tr2.opt_emb.timestep(), (1u64 << 24) + 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nonzero_rank_checkpoint_has_no_loss_placeholder() {
        let cfg = CubicConfig {
            parallelism: Parallelism::OneD,
            edge: 2,
            ..CubicConfig::default()
        };
        let dir = tmpdir("no-placeholder");
        // Saving is IO-only — no endpoint needed.
        for rank in 0..2 {
            let tr = TrainerRank::new(&cfg, rank);
            tr.save_checkpoint(&dir, 3, &[1.0, 0.9, 0.8]).unwrap();
        }
        let r0 = checkpoint::read_tensors(&dir.join("rank-0.bin")).unwrap();
        assert!(r0.contains_key("meta.losses"));
        let r1 = checkpoint::read_tensors(&dir.join("rank-1.bin")).unwrap();
        assert!(
            !r1.contains_key("meta.losses"),
            "non-zero ranks must not write a loss placeholder"
        );
        assert_eq!(decode_u64(&r1, "meta.steps_done").unwrap(), 3);
        std::fs::remove_dir_all(&dir).ok();
        // Rank 0 with an empty history writes no placeholder either — the
        // loader treats absence as "no history yet".
        let dir2 = tmpdir("no-history");
        TrainerRank::new(&cfg, 0).save_checkpoint(&dir2, 0, &[]).unwrap();
        let r0 = checkpoint::read_tensors(&dir2.join("rank-0.bin")).unwrap();
        assert!(!r0.contains_key("meta.losses"));
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn checkpoint_save_io_error_is_typed() {
        use crate::comm::NetModel;
        use crate::spmd::run_spmd;
        // Route the checkpoint dir through a regular file: `create_dir_all`
        // fails with NotADirectory even when running as root (permission
        // bits would not stop root).
        let blocker = tmpdir("io-blocker");
        std::fs::create_dir_all(&blocker).unwrap();
        let file = blocker.join("file");
        std::fs::write(&file, b"not a directory").unwrap();
        let dir = file.join("sub");
        let cfg = CubicConfig {
            parallelism: Parallelism::Seq,
            edge: 1,
            ..CubicConfig::default()
        };
        let outcomes = run_spmd(1, NetModel::zero(), move |rank, ep| {
            let tr = Box::new(TrainerRank::new(&cfg, rank));
            tr.run_supervised(ep, 0, 1, 0, Some(&dir), Vec::new(), Vec::new())
        });
        let out = &outcomes[0];
        assert!(!out.completed);
        assert!(out.trainer.is_some(), "state must survive a failed save");
        assert_eq!(out.losses.len(), 1, "the step itself completed");
        match &out.error {
            Some(CommError::Checkpoint { rank: 0, msg }) => assert!(!msg.is_empty()),
            other => panic!("expected typed checkpoint error, got {other:?}"),
        }
        std::fs::remove_dir_all(&blocker).ok();
    }

    #[test]
    fn head_loss_decreases_under_its_own_gradient() {
        let cfg = ModelConfig::tiny();
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut head = Head::init(&cfg, &mut rng);
        let x = Tensor::randn(&[8, cfg.hidden], 1.0, &mut rng);
        let targets: Vec<usize> = (0..8).map(|i| i % cfg.vocab).collect();
        let (l0, _, g) = head.loss_and_grads(&x, &targets, cfg.eps);
        // SGD on the head weights only.
        head.w.axpy(-1.0, &g.w.scale(1.0));
        head.b.axpy(-1.0, &g.b.scale(1.0));
        let (l1, _, _) = head.loss_and_grads(&x, &targets, cfg.eps);
        assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
    }
}
