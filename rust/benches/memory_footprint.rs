//! Ablation bench for the paper's §3.1.1 memory claim: per-rank parameter
//! *and activation* bytes for one transformer layer under each parallelism
//! — measured from the actual shard shapes the model allocates.
//!
//! Expected shape: weights are 1/P everywhere, but 1-D replicates
//! activations (the O(1) term the paper's load-balanced 3-D storage
//! removes); 2-D and 3-D hold 1/P of both.
//!
//! Run: `cargo bench --bench memory_footprint`

use cubic::config::ModelConfig;
use cubic::metrics::{fmt_bytes, Table};
use cubic::model::ParEnv;
use cubic::topology::{HybridInner, Parallelism};

fn main() {
    let cfg = ModelConfig { layers: 1, ..ModelConfig::paper(4096, 16) };
    let rows = cfg.batch * cfg.seq;
    let mut t = Table::new(&[
        "Approach", "# GPUs", "weights/rank", "activations/rank", "total/rank", "x Seq",
    ]);
    let seq_total = {
        let env = ParEnv::seq();
        let w = env.phantom_block(&cfg).numel() * 4;
        let (r, c) = env.activation_shape(rows, cfg.hidden);
        (w + r * c * 4) as f64
    };
    let cases = [
        (Parallelism::Seq, 1usize),
        (Parallelism::OneD, 8),
        (Parallelism::OneD, 64),
        (Parallelism::TwoD, 8),
        (Parallelism::ThreeD, 2),
        (Parallelism::ThreeD, 4),
        // 2.5-D holds weights at 1/P but activations at 1/p² (d-fold
        // replicated) — the Tesseract memory side of the trade-off.
        (Parallelism::TwoFiveD { depth: 4 }, 4), // 64
        // Hybrid replicates weights per data-parallel replica and splits
        // batch rows.
        (Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 4), // 64
    ];
    for (par, edge) in cases {
        let world = par.world_size(edge);
        // Worst-case rank (rank 0 owns every diagonal in 3-D).
        let mut w_max = 0usize;
        let mut a_max = 0usize;
        for rank in 0..world {
            let env = ParEnv::new(par, edge, rank);
            let w = env.phantom_block(&cfg).numel() * 4;
            let (r, c) = env.activation_shape(rows, cfg.hidden);
            w_max = w_max.max(w);
            a_max = a_max.max(r * c * 4);
        }
        let total = (w_max + a_max) as f64;
        t.row(&[
            par.name().to_string(),
            world.to_string(),
            fmt_bytes(w_max as u64),
            fmt_bytes(a_max as u64),
            fmt_bytes(total as u64),
            format!("{:.3}", total / seq_total),
        ]);
    }
    println!("## §3.1.1 — per-rank memory, one layer (weights + input activation)\n");
    println!("{}", t.to_markdown());
    println!("\nPaper claim: 3-D memory O(1/P) incl. activations; 1-D replicates activations.");
    // Shape-only accounting: the copy-on-write counter must stay at zero.
    assert_eq!(cubic::metrics::bytes_cloned(), 0, "phantom accounting must not clone tensor data");
}
