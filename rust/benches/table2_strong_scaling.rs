//! Regenerates **paper Table 2** (strong scaling): fixed problem size
//! (hidden 3072, seq 512), 8 → 64 GPUs; headline claim: 3-D beats 1-D by
//! 2.32× and 2-D by 1.57× in average step time at 64 GPUs.
//!
//! Also times the two post-paper meshes (2.5-D Tesseract and the hybrid
//! data×tensor group) on the same fixed problem at 64 GPUs, so the
//! spectrum ranks at equal world size.
//!
//! Run: `cargo bench --bench table2_strong_scaling`

use cubic::bench::{render, run_rows, strong_scaling_speedups, table2_rows};
use cubic::comm::NetModel;
use cubic::config::ModelConfig;
use cubic::engine::time_core_step;
use cubic::topology::{HybridInner, Parallelism};

fn main() {
    let net = NetModel::longhorn_v100();
    let rows = table2_rows();
    eprintln!("table2: timing {} rows on the virtual cluster...", rows.len());
    let results = run_rows(&rows, &net);
    println!("{}", render("Table 2 — strong scaling (measured vs paper)", &results));

    let (s1, s2) = strong_scaling_speedups(&results);
    println!("\n### Headline speedups at 64 GPUs (avg step time)\n");
    println!("- 3-D vs 1-D: {s1:.2}x measured (paper 2.32x = 0.550/0.237·…; raw 0.550/0.359 = 1.53x)");
    println!("- 3-D vs 2-D: {s2:.2}x measured (paper 1.57x; raw 0.497/0.359 = 1.38x)");
    println!("\nShape criteria: 3-D fastest at 64 GPUs; 2-D scales down with P while 1-D plateaus.");

    // Post-paper meshes on the fixed problem at 64 GPUs (batch 24 like the
    // 2-D/3-D rows; 2.5-D as 4x4x4, hybrid as 4 replicas x 4x4 SUMMA).
    println!("\n### Beyond the paper: 2.5-D and hybrid at 64 GPUs (same problem)\n");
    let cfg = ModelConfig { layers: cubic::bench::LAYERS, ..ModelConfig::paper(3072, 24) };
    for (label, par, edge) in [
        ("2.5d 4x4x4", Parallelism::TwoFiveD { depth: 4 }, 4usize),
        (
            "hybrid 4x(4x4)",
            Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD },
            4,
        ),
    ] {
        let t = time_core_step(&cfg, par, edge, net.clone()).expect("timing run failed");
        println!(
            "- {label}: fwd {:.3}s bwd {:.3}s avg step {:.4}s",
            t.forward_s,
            t.backward_s,
            t.avg_step_time(24)
        );
    }
    // Timing sweeps are phantom-mode: no tensor data may be copied.
    assert_eq!(cubic::metrics::bytes_cloned(), 0, "phantom sweeps must not clone tensor data");
}
