//! Regenerates **paper Table 2** (strong scaling): fixed problem size
//! (hidden 3072, seq 512), 8 → 64 GPUs; headline claim: 3-D beats 1-D by
//! 2.32× and 2-D by 1.57× in average step time at 64 GPUs.
//!
//! Run: `cargo bench --bench table2_strong_scaling`

use cubic::bench::{render, run_rows, strong_scaling_speedups, table2_rows};
use cubic::comm::NetModel;

fn main() {
    let net = NetModel::longhorn_v100();
    let rows = table2_rows();
    eprintln!("table2: timing {} rows on the virtual cluster...", rows.len());
    let results = run_rows(&rows, &net);
    println!("{}", render("Table 2 — strong scaling (measured vs paper)", &results));

    let (s1, s2) = strong_scaling_speedups(&results);
    println!("\n### Headline speedups at 64 GPUs (avg step time)\n");
    println!("- 3-D vs 1-D: {s1:.2}x measured (paper 2.32x = 0.550/0.237·…; raw 0.550/0.359 = 1.53x)");
    println!("- 3-D vs 2-D: {s2:.2}x measured (paper 1.57x; raw 0.497/0.359 = 1.38x)");
    println!("\nShape criteria: 3-D fastest at 64 GPUs; 2-D scales down with P while 1-D plateaus.");
    // Timing sweeps are phantom-mode: no tensor data may be copied.
    assert_eq!(cubic::metrics::bytes_cloned(), 0, "phantom sweeps must not clone tensor data");
}
