//! Regenerates **paper Table 1** (weak scaling): fwd/bwd/avg-step time for
//! 1-D @ {8,16,36,64}, 2-D @ {16,36,64} and 3-D @ {8,64} GPUs, with the
//! per-approach batch/hidden growth the paper uses (seq 512).
//!
//! Run: `cargo bench --bench table1_weak_scaling`
//! Output: markdown table with measured vs paper columns + the weak-scaling
//! growth factors (the paper's claim: 3-D's avg step time rises slowest).

use cubic::bench::{render, run_rows, table1_rows, RowResult};
use cubic::comm::NetModel;
use cubic::topology::Parallelism;

fn main() {
    let net = NetModel::longhorn_v100();
    let rows = table1_rows();
    eprintln!("table1: timing {} rows on the virtual cluster...", rows.len());
    let results = run_rows(&rows, &net);
    println!("{}", render("Table 1 — weak scaling (measured vs paper)", &results));

    println!("\n### Weak-scaling growth (avg step time, smallest -> largest GPU count)\n");
    for par in [Parallelism::OneD, Parallelism::TwoD, Parallelism::ThreeD] {
        let rs: Vec<&RowResult> = results.iter().filter(|r| r.spec.approach == par).collect();
        let growth = rs.last().unwrap().avg_step() / rs[0].avg_step();
        let paper_growth = rs.last().unwrap().spec.paper_avg / rs[0].spec.paper_avg;
        println!(
            "- {:3}: x{:.2} measured (paper x{:.2})",
            par.name(),
            growth,
            paper_growth
        );
    }
    println!("\nPaper claim: 3-D has the slowest rising average step time.");
    // Timing sweeps are phantom-mode: no tensor data may be copied.
    assert_eq!(cubic::metrics::bytes_cloned(), 0, "phantom sweeps must not clone tensor data");
}
