//! Host-side performance microbenches (§Perf of EXPERIMENTS.md): wall-clock
//! throughput of the hot paths — the SIMD matmul microkernels (per dispatch
//! variant), the collective engine, and the phantom-mode scheduling
//! overhead that bounds how fast the table benches can sweep configurations.
//!
//! Since the PR-2 kernel refactor this bench reports **GF/s per kernel
//! variant** (scalar fallback vs the runtime-dispatched SIMD kernel, with
//! the ratio that quantifies the win) next to the **allocation counters**:
//! the transport send path must clone 0 bytes, a steady-state ring
//! all-reduce must copy-on-write 0 bytes AND serve every scratch buffer
//! from the recycling pool (0 pool misses after the warmup iteration).
//! Both properties are asserted, not just printed.
//!
//! Since PR 3 it also measures the multi-core driver: serial (1-thread) vs
//! threaded GF/s at 256³ with a hard assert that threading is no slower
//! (≥ 0.95× serial, the noise guard band) — bit-exactness across thread
//! counts is the test suite's job (`tests/kernel_threads.rs`), this bench
//! pins the *throughput* side of the tentpole.
//!
//! Run: `cargo bench --bench microbench`
//! CI:  `cargo bench --bench microbench -- --smoke` (short iterations,
//!      same asserts, no JSON side effect).
//! Side effect (full run only): rewrites `BENCH_PR2.json`,
//! `BENCH_PR3.json`, `BENCH_PR5.json` (per-parallelism-kind phantom
//! step time + comm volume at 64 ranks), `BENCH_PR6.json` (overlap
//! speedup + exposed-comm fraction per kind at 64 ranks), and the later
//! per-PR records (`BENCH_PR7..10.json`: fault-recovery cost, pipeline
//! bubbles, serving throughput, ZeRO optimizer-memory savings) at the
//! repo root with the headline numbers, and fills the previously-null
//! measured fields of `BENCH_PR1.json` with the scalar-variant numbers.

use cubic::collectives::all_reduce;
use cubic::comm::{NetModel, World};
use cubic::metrics::{bytes_cloned, Stopwatch};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::kernel::{self, gemm_strided_t, Kernel};
use cubic::tensor::{matmul_flops, Tensor};

fn randv(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// GF/s of one kernel variant on an (m,k,n) matmul through the packed
/// driver, per form. Operates on raw slices so a *specific* kernel can be
/// driven regardless of what the dispatcher selected, and pins the driver
/// to one thread so this stays a *kernel* measurement (thread scaling is
/// measured separately by `bench_threads`).
fn bench_kernel_form(
    kern: Kernel,
    form: &str,
    m: usize,
    k: usize,
    n: usize,
    iters: usize,
) -> f64 {
    let a = randv(1, m * k);
    let b = randv(2, k * n);
    let mut c = vec![0.0f32; m * n];
    let (ars, aks, brs, bcs) = match form {
        "nn" => (k, 1, n, 1),
        "nt" => (k, 1, 1, k), // b stored (n,k), read transposed
        "tn" => (1, m, n, 1), // a stored (k,m), read transposed
        _ => unreachable!(),
    };
    // Warm-up (also faults in the pack scratch).
    gemm_strided_t(kern, 1, m, n, k, &a, ars, aks, &b, brs, bcs, &mut c);
    let sw = Stopwatch::start();
    for _ in 0..iters {
        c.fill(0.0);
        gemm_strided_t(kern, 1, m, n, k, &a, ars, aks, &b, brs, bcs, &mut c);
    }
    let secs = sw.seconds();
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!(
        "matmul_{form} {m}x{k}x{n} [{:>8}]: {gflops:7.2} GF/s  ({:.3} ms/iter, sink {:.1})",
        kern.name,
        1e3 * secs / iters as f64,
        c[0]
    );
    gflops
}

/// Threaded-vs-serial driver comparison at the headline 256³ shape
/// (dispatched kernel, nn form). Best-of-3 wall-clock per variant to damp
/// scheduler noise on small CI hosts. Returns (serial GF/s, threaded GF/s).
fn bench_threads(iters: usize) -> (f64, f64) {
    let kern = kernel::selected();
    let t = kernel::threads::selected_threads();
    let dim = 256;
    let a = randv(3, dim * dim);
    let b = randv(4, dim * dim);
    let mut c = vec![0.0f32; dim * dim];
    let mut best = [0.0f64; 2];
    for (which, threads) in [1usize, t].into_iter().enumerate() {
        // Warm-up (faults in scratch, spawns pool workers on first use).
        c.fill(0.0);
        gemm_strided_t(kern, threads, dim, dim, dim, &a, dim, 1, &b, dim, 1, &mut c);
        for _rep in 0..3 {
            let sw = Stopwatch::start();
            for _ in 0..iters {
                c.fill(0.0);
                gemm_strided_t(kern, threads, dim, dim, dim, &a, dim, 1, &b, dim, 1, &mut c);
            }
            let gf = iters as f64 * 2.0 * (dim as f64).powi(3) / sw.seconds() / 1e9;
            best[which] = best[which].max(gf);
        }
    }
    println!(
        "matmul_nn 256^3 driver: serial {:.2} GF/s, {t} threads {:.2} GF/s ({:.2}x), \
         pool: {} threaded jobs, {} serial fallbacks (sink {:.1})",
        best[0],
        best[1],
        best[1] / best[0],
        kernel::threads::threaded_jobs(),
        kernel::threads::serial_fallbacks(),
        c[0]
    );
    (best[0], best[1])
}

/// Matmul through the public Tensor API (dispatched kernel), reporting
/// bytes cloned — the historical PR-1 shape of the bench.
fn bench_matmul_api(label: &str, m: usize, k: usize, n: usize, iters: usize) -> f64 {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut sink = a.matmul(&b).at2(0, 0);
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    for _ in 0..iters {
        sink += a.matmul(&b).at2(0, 0);
    }
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!(
        "matmul_nn {label} [dispatch={}]: {gflops:.2} GF/s  ({:.3} ms/iter, {cloned} B cloned, sink {sink:.1})",
        kernel::selected_name(),
        1e3 * secs / iters as f64
    );
    gflops
}

/// Pure transport benchmark: the send path must never copy payload data.
/// Returns the (exactly measured) bytes cloned by N sends of a large
/// tensor — the acceptance number for the zero-copy refactor.
fn bench_send_path(elems: usize, iters: usize) -> u64 {
    let mut world = World::new(2, NetModel::zero());
    let mut e0 = world.endpoint(0);
    let mut e1 = world.endpoint(1);
    let payload = Tensor::full(&[elems], 1.0);
    let its = iters as u64;
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    let h = std::thread::spawn(move || {
        for i in 0..its {
            e0.send(1, i, &payload);
        }
    });
    for i in 0..its {
        let got = e1.recv(0, i);
        assert_eq!(got.numel(), elems);
    }
    h.join().unwrap();
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    println!(
        "send path: {iters} x {} KiB messages in {:.3} ms — {cloned} B cloned (expect 0)",
        elems * 4 / 1024,
        1e3 * secs
    );
    cloned
}

/// Materialized ring all-reduce: ms/op, cloned bytes and pool misses per
/// rank per op after a warmup iteration — the steady-state allocation
/// figures. Each iteration ends on a real barrier so cross-thread buffer
/// reclaim completes before the next request (see collectives tests).
fn bench_collectives(world: usize, elems: usize, iters: usize) -> (f64, f64, u64) {
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    let its = iters;
    let stats = run_spmd(world, NetModel::zero(), move |rank, ep| {
        let group: Vec<usize> = (0..world).collect();
        let t = Tensor::full(&[elems], rank as f32);
        // Warmup: populates the recycling pool (the only allocations).
        let r = all_reduce(ep, &group, &t);
        drop(r);
        ep.barrier_wait();
        let m0 = ep.stats.pool_misses;
        for _ in 0..its {
            let r = all_reduce(ep, &group, &t);
            drop(r);
            ep.barrier_wait();
        }
        ep.stats.pool_misses - m0
    });
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    let misses_after_warmup: u64 = stats.iter().sum();
    let cloned_per_rank_op = cloned as f64 / (world * iters) as f64;
    let gb = (iters * world * elems * 4) as f64 / 1e9;
    println!(
        "all_reduce world={world} n={elems}: {:.3} ms/op, {:.2} GB/s aggregate, \
         {cloned_per_rank_op:.0} B cloned/rank/op, {misses_after_warmup} pool misses after warmup \
         (expect 0 and 0)",
        1e3 * secs / iters as f64,
        gb / secs,
    );
    (1e3 * secs / iters as f64, cloned_per_rank_op, misses_after_warmup)
}

fn bench_phantom_overhead(iters: usize) {
    // Per-op cost of the phantom scheduling path: 8-rank 3-D matmul.
    use cubic::dist::Dirs;
    use cubic::parallel::threed::{mm_nn, Ctx3D};
    use cubic::topology::Cube;
    let sw = Stopwatch::start();
    run_spmd(8, NetModel::longhorn_v100(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(2), rank);
        let a = Tensor::phantom(&[1024, 2048]);
        let b = Tensor::phantom(&[2048, 1024]);
        for _ in 0..iters {
            let _ = mm_nn(ep, &ctx, &a, &b, Dirs::canonical());
        }
    });
    let secs = sw.seconds();
    println!("phantom mm_nn (8 ranks): {:.1} µs/op/rank", 1e6 * secs / iters as f64);
}

struct KernelNumbers {
    scalar: [f64; 3],   // nn, nt, tn at 256³
    dispatch: [f64; 3], // same, through the selected kernel
}

fn fmt_opt(v: f64) -> String {
    format!("{v:.3}")
}

fn write_json(kn: &KernelNumbers, send_cloned: u64, ar_ms: f64, ar_cloned: f64, ar_misses: u64) {
    let ratio: Vec<f64> =
        kn.scalar.iter().zip(&kn.dispatch).map(|(s, d)| if *s > 0.0 { d / s } else { 0.0 }).collect();
    let sel = kernel::selected_name();
    let path2 = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR2.json");
    // Fixed "simd" key (the variant name lives in kernel_selected), so the
    // JSON stays valid even when the dispatched kernel IS the scalar
    // fallback (no AVX2/NEON host, CUBIC_KERNEL=scalar, --no-default-features).
    let json2 = format!(
        "{{\n  \"pr\": 2,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"wall-clock on the build host; regenerate locally for comparable numbers\",\n  \
         \"kernel_selected\": \"{sel}\",\n  \
         \"matmul_256_gflops\": {{\n    \
         \"scalar\": {{ \"nn\": {}, \"nt\": {}, \"tn\": {} }},\n    \
         \"simd\": {{ \"nn\": {}, \"nt\": {}, \"tn\": {} }},\n    \
         \"simd_over_scalar\": {{ \"nn\": {:.2}, \"nt\": {:.2}, \"tn\": {:.2} }}\n  }},\n  \
         \"send_path_bytes_cloned\": {send_cloned},\n  \
         \"all_reduce_8rank_65536\": {{\n    \"ms_per_op\": {ar_ms:.4},\n    \
         \"bytes_cloned_per_rank_per_op\": {ar_cloned:.1},\n    \
         \"pool_misses_after_warmup\": {ar_misses},\n    \
         \"note\": \"steady state: 0 CoW bytes and 0 buffer allocations per op — the reduce-scatter accumulator, the all-gather output assembly and any padded chunks are all served by the per-endpoint recycling pool after the warmup iteration (asserted, not just measured). PR-1 baseline: one accumulator CoW per rank per op (chunk bytes) plus a fresh output concatenation.\"\n  }}\n}}\n",
        fmt_opt(kn.scalar[0]),
        fmt_opt(kn.scalar[1]),
        fmt_opt(kn.scalar[2]),
        fmt_opt(kn.dispatch[0]),
        fmt_opt(kn.dispatch[1]),
        fmt_opt(kn.dispatch[2]),
        ratio[0],
        ratio[1],
        ratio[2],
    );
    match std::fs::write(path2, &json2) {
        Ok(()) => println!("\nwrote {path2}"),
        Err(e) => eprintln!("\ncould not write {path2}: {e}"),
    }
    // Fill the historical PR-1 record's null fields with the scalar-variant
    // numbers (PR 1's blocked-loop kernels were superseded by the packed
    // scalar microkernel; this is the closest measurable stand-in).
    let path1 = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR1.json");
    let json1 = format!(
        "{{\n  \"pr\": 1,\n  \"generated_by\": \"cargo bench --bench microbench (rerun after the PR-2 kernel refactor)\",\n  \
         \"host\": \"wall-clock on the build host; regenerate locally for comparable numbers\",\n  \
         \"matmul_nn_256\": {{ \"gflops\": {} }},\n  \
         \"matmul_nt_256\": {{ \"gflops\": {} }},\n  \
         \"send_path_bytes_cloned\": {send_cloned},\n  \
         \"all_reduce_8rank_65536\": {{\n    \"ms_per_op\": {ar_ms:.4},\n    \
         \"bytes_cloned_per_rank_per_op\": {ar_cloned:.1},\n    \
         \"note\": \"measured with the PR-2 scalar fallback microkernel (PR 1's hand-blocked loops were replaced by the packed microkernel driver); the PR-1 accumulator CoW was eliminated by the recycling pool, hence 0 cloned bytes — see BENCH_PR2.json\"\n  }}\n}}\n",
        fmt_opt(kn.scalar[0]),
        fmt_opt(kn.scalar[1]),
    );
    match std::fs::write(path1, &json1) {
        Ok(()) => println!("updated {path1}"),
        Err(e) => eprintln!("could not update {path1}: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("## Host microbenchmarks (wall-clock){}\n", if smoke { " — smoke mode" } else { "" });
    println!(
        "kernel dispatch: selected = {}, available = {:?}, gemm threads = {}\n",
        kernel::selected_name(),
        kernel::available().iter().map(|k| k.name).collect::<Vec<_>>(),
        kernel::threads::selected_threads()
    );
    cubic::tensor::reset_flop_counter();
    cubic::metrics::reset_pack_bytes();

    // Per-kernel-variant throughput at the headline 256³ shape.
    let dim = 256;
    let iters = if smoke { 2 } else { 20 };
    let scalar = kernel::available()[0];
    let dispatch = kernel::selected();
    let mut kn = KernelNumbers { scalar: [0.0; 3], dispatch: [0.0; 3] };
    for (i, form) in ["nn", "nt", "tn"].iter().enumerate() {
        kn.scalar[i] = bench_kernel_form(scalar, form, dim, dim, dim, iters);
        if dispatch.name != scalar.name {
            kn.dispatch[i] = bench_kernel_form(dispatch, form, dim, dim, dim, iters);
        } else {
            kn.dispatch[i] = kn.scalar[i];
        }
    }
    if dispatch.name != scalar.name {
        println!(
            "scalar -> {}: nn {:.2}x, nt {:.2}x, tn {:.2}x\n",
            dispatch.name,
            kn.dispatch[0] / kn.scalar[0],
            kn.dispatch[1] / kn.scalar[1],
            kn.dispatch[2] / kn.scalar[2]
        );
    }

    // Threaded driver vs serial at the headline shape. The assert is the
    // CI smoke pin for the PR-3 multi-core driver: threading must never
    // cost throughput at 256³ (5% guard band absorbs wall-clock noise on
    // shared runners; parity/bit-exactness is pinned by the test suite).
    let (mut serial_gf, mut threaded_gf) = bench_threads(if smoke { 4 } else { iters });
    let mut threads_ratio = if serial_gf > 0.0 { threaded_gf / serial_gf } else { 0.0 };
    if kernel::threads::selected_threads() > 1 {
        // Smoke mode runs on shared CI runners with few timed iterations, so
        // its guard band is wider, and a below-floor reading gets one full
        // re-measure before failing (a noisy-neighbor burst doesn't span two
        // best-of-3 measurements; a real regression fails both). The full
        // run holds the real bar.
        let floor = if smoke { 0.80 } else { 0.95 };
        if threads_ratio < floor {
            eprintln!("threads ratio {threads_ratio:.2}x below floor {floor}; re-measuring once");
            (serial_gf, threaded_gf) = bench_threads(if smoke { 4 } else { iters });
            threads_ratio = if serial_gf > 0.0 { threaded_gf / serial_gf } else { 0.0 };
        }
        assert!(
            threads_ratio >= floor,
            "threaded driver must be no slower than serial at 256^3 \
             (got {threads_ratio:.2}x, floor {floor})"
        );
    }

    // Dispatched end-to-end API shapes (counter sanity: matmul clones 0).
    bench_matmul_api("256x256x256", 256, 256, 256, iters);
    if !smoke {
        bench_matmul_api("512x512x512", 512, 512, 512, 4);
        bench_matmul_api("128x1024x128", 128, 1024, 128, 20);
    }

    let send_cloned = bench_send_path(1 << 18, if smoke { 10 } else { 100 });
    assert_eq!(send_cloned, 0, "transport send path must be zero-copy");

    let coll_iters = if smoke { 5 } else { 50 };
    bench_collectives(4, 1 << 16, coll_iters);
    let (ar_ms, ar_cloned, ar_misses) = bench_collectives(8, 1 << 16, coll_iters);
    // Exact pins (this process owns the counters): a steady-state
    // all-reduce clones nothing (the accumulator fill is an explicit write
    // into a pooled buffer, not a CoW) and allocates nothing (the pool
    // serves every scratch request after warmup). Any reintroduced per-hop
    // copy or per-call allocation fails here.
    assert_eq!(ar_cloned, 0.0, "steady-state all-reduce must not copy-on-write");
    assert_eq!(ar_misses, 0, "steady-state all-reduce must not allocate after warmup");

    bench_phantom_overhead(if smoke { 20 } else { 200 });
    let _ = matmul_flops();
    // Pack traffic vs useful work: a driver regression that re-packs a
    // panel per tile (instead of per block/strip) blows this ratio up by
    // ~an order of magnitude long before it shows in wall-clock noise.
    let pack_b = cubic::metrics::pack_bytes();
    let flops_total = matmul_flops();
    println!(
        "gemm pack traffic: {pack_b} B for {flops_total} flops ({:.4} packed bytes/flop)",
        pack_b as f64 / flops_total.max(1) as f64
    );
    println!(
        "pool counters (global): {} hits, {} allocs",
        cubic::metrics::pool_hits(),
        cubic::metrics::pool_allocs()
    );
    if smoke {
        println!("\nsmoke mode: skipping BENCH_PR*.json rewrite");
    } else {
        write_json(&kn, send_cloned, ar_ms, ar_cloned, ar_misses);
        write_json3(serial_gf, threaded_gf, ar_misses, pack_b as f64 / flops_total.max(1) as f64);
        write_json5();
        write_json6();
        write_json7();
        write_json8();
        write_json9();
        write_json10();
    }
}

/// PR-10 headline numbers: ZeRO optimizer-state sharding. For hybrid
/// meshes at r ∈ {2, 4, 8} replicas of a 4×4 SUMMA grid this records the
/// per-rank gradient + Adam-moment bytes at zero_stage ∈ {0, 1, 2} —
/// computed from the *real* phantom shard shapes of the paper model, the
/// same `param_numels` → `optimizer_bytes_per_rank` path `cubic plan`
/// prints — plus the phantom step time, which ZeRO leaves unchanged
/// (reduce-scatter + all-gather send exactly the bytes of the all-reduce
/// they replace; bit-identity is pinned in tests/model_parity.rs).
fn write_json10() {
    use cubic::config::ModelConfig;
    use cubic::costmodel::optimizer_bytes_per_rank;
    use cubic::dist::ShardSpec;
    use cubic::engine::time_core_step;
    use cubic::model::DenseBlock;
    use cubic::topology::{HybridInner, Parallelism};
    let net = cubic::comm::NetModel::longhorn_v100();
    let edge = 4; // 4×4 inner grid, 16 ranks per replica
    let cfg = ModelConfig::paper(4096, 64);
    let mut entries = Vec::new();
    for r in [2usize, 4, 8] {
        let par = Parallelism::Hybrid { replicas: r, inner: HybridInner::TwoD };
        let world = par.world_size(edge);
        // Shard shapes are identical across replicas; scan one replica's
        // inner ranks for the worst-case rank (vector ownership varies).
        let iw = world / r;
        let max_opt = |stage: usize| -> u64 {
            (0..iw)
                .map(|rank| {
                    let spec = ShardSpec::for_parallelism(par, edge, rank);
                    let numels = DenseBlock::phantom(&cfg).shard(&spec).param_numels();
                    optimizer_bytes_per_rank(&numels, r as u64, stage)
                })
                .max()
                .unwrap()
        };
        let (z0, z1, z2) = (max_opt(0), max_opt(1), max_opt(2));
        let t = time_core_step(&cfg, par, edge, net.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR10: r={r} hybrid timing failed: {e}"));
        let step = t.forward_s + t.backward_s;
        entries.push(format!(
            "    \"r{r}x2d\": {{ \"mesh\": \"{}\", \"world\": {world}, \"replicas\": {r}, \
             \"opt_bytes_per_rank_zero0\": {z0}, \"opt_bytes_per_rank_zero1\": {z1}, \
             \"opt_bytes_per_rank_zero2\": {z2}, \"step_virtual_s\": {step:.6} }}",
            par.mesh_desc(edge),
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR10.json");
    let json = format!(
        "{{\n  \"pr\": 10,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual-clock phantom mode; deterministic for a given NetModel\",\n  \
         \"model\": \"hidden 4096, batch 64, seq 512, per layer (ModelConfig::paper)\",\n  \
         \"zero_phantom_step\": {{\n{}\n  }},\n  \
         \"note\": \"opt bytes = per-rank gradient + Adam moment residency from the real \
         phantom shard shapes (worst rank of one replica group). zero1 partitions the \
         moments 1/r, zero2 also partitions gradient residency; step_virtual_s is the \
         same ZeRO on or off because reduce-scatter + all-gather is exactly the ring \
         all-reduce's two phases at identical volume — the bitwise pin is \
         tests/model_parity.rs::zero_training_is_bitwise_identical_to_replicated_hybrid.\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-9 headline numbers: inference serving. Every mesh kind at 64 ranks
/// serves the paper-shape model in phantom mode — KV-cached decode over the
/// virtual clock — and the continuous-batching simulator replays a seeded
/// open-loop Poisson trace at 0.5×/1×/2× the engine's measured service
/// rate, recording tokens/sec/rank, per-rank KV bytes, and p50/p99 request
/// latency per arrival rate. Deterministic for a given NetModel and seed
/// (the CI smoke asserts two same-seed runs produce an identical trace).
fn write_json9() {
    use cubic::config::{ModelConfig, ServeConfig};
    use cubic::costmodel::kv_cache_bytes_per_rank;
    use cubic::engine::time_serve;
    use cubic::topology::{HybridInner, Parallelism, PipelineInner};
    let net = cubic::comm::NetModel::longhorn_v100();
    let cases: [(&str, Parallelism, usize); 6] = [
        ("1d", Parallelism::OneD, 64),
        ("2d", Parallelism::TwoD, 8),
        ("3d", Parallelism::ThreeD, 4),
        ("2.5d", Parallelism::TwoFiveD { depth: 4 }, 4),
        ("dp8x1d", Parallelism::Hybrid { replicas: 8, inner: HybridInner::OneD }, 8),
        (
            "pp4x2d",
            Parallelism::Pipeline { stages: 4, micro_batches: 8, inner: PipelineInner::TwoD },
            4,
        ),
    ];
    let serve = ServeConfig {
        slots: 64,
        max_seq: 160,
        prompt_len: 128,
        gen_len: 32,
        requests: 64,
        arrival_rate: 0.0, // per-case sweep below
        seed: 9,
    };
    let mut entries = Vec::new();
    for (name, par, edge) in cases {
        let world = par.world_size(edge);
        let stages = match par {
            Parallelism::Pipeline { stages, .. } => stages,
            _ => 1,
        };
        // One layer per stage, matching the per-layer-stack convention of
        // the training tables.
        let cfg = ModelConfig { layers: stages, ..ModelConfig::paper(4096, 64) };
        let m = time_serve(&cfg, &serve, par, edge, net.clone(), true, serve.seed)
            .unwrap_or_else(|e| panic!("BENCH_PR9: {name} serve timing failed: {e}"));
        let kv_bytes = cfg.layers as u64
            * kv_cache_bytes_per_rank(
                par,
                edge,
                0,
                serve.slots as u64,
                cfg.heads as u64,
                (cfg.hidden / cfg.heads) as u64,
                serve.max_seq as u64,
            );
        let service_rate =
            serve.slots as f64 / (m.prefill_s + m.decode_total_s).max(1e-12);
        let rates: Vec<String> = [0.5, 1.0, 2.0]
            .iter()
            .map(|mult| {
                let rate = mult * service_rate;
                let sv = ServeConfig { arrival_rate: rate, ..serve.clone() };
                let sim = cubic::serve::simulate(&sv, m.prefill_s, &m.decode_step_s);
                format!(
                    "{{ \"rate_req_s\": {rate:.4}, \"p50_s\": {:.6}, \"p99_s\": {:.6}, \
                     \"mean_s\": {:.6}, \"max_concurrent\": {} }}",
                    sim.p50, sim.p99, sim.mean, sim.max_concurrent
                )
            })
            .collect();
        entries.push(format!(
            "    \"{name}\": {{ \"mesh\": \"{}\", \"world\": {world}, \
             \"tokens_per_sec_per_rank\": {:.2}, \"prefill_virtual_s\": {:.6}, \
             \"decode_step_virtual_s\": {:.6}, \"kv_bytes_per_rank\": {kv_bytes}, \
             \"rates\": [{}] }}",
            par.mesh_desc(edge),
            m.tokens_per_sec_per_rank,
            m.prefill_s,
            m.decode_total_s / serve.gen_len.max(1) as f64,
            rates.join(", "),
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR9.json");
    let json = format!(
        "{{\n  \"pr\": 9,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual-clock phantom mode; deterministic for a given NetModel\",\n  \
         \"model\": \"hidden 4096, 64-dim heads, seq window 160 (ModelConfig::paper), 1 layer per stage\",\n  \
         \"serve_phantom\": {{\n{}\n  }},\n  \
         \"note\": \"KV-cached serving at 64 ranks: 64 slots, prompt 128, gen 32, seeded \
         open-loop Poisson arrivals replayed by the continuous-batching simulator at \
         0.5x/1x/2x the measured service rate. tokens_per_sec_per_rank is decode-only \
         steady state on the virtual clock; tests/serve_parity.rs pins decode bitwise \
         against the full-sequence forward on every mesh kind.\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-8 headline numbers: pipeline parallelism across the 5-D product
/// space. Every pipeline point at 64 ranks (paper-shape model, one layer
/// per stage) is timed in phantom mode against the same inner mesh running
/// the whole stack unpipelined, alongside the costmodel's closed-form
/// bubble fraction `(s-1)/(m+s-1)` — the engine-vs-recurrence bitwise pin
/// lives in the costmodel tests; this persists the ranking the scheduled
/// bench job uploads.
fn write_json8() {
    use cubic::config::ModelConfig;
    use cubic::costmodel::pipeline_bubble_fraction;
    use cubic::engine::time_core_step;
    use cubic::topology::{HybridInner, Parallelism, PipelineInner};
    let net = cubic::comm::NetModel::longhorn_v100();
    let cases: [(&str, usize, usize, PipelineInner, usize); 5] = [
        ("pp2x1d", 2, 8, PipelineInner::OneD, 32),
        ("pp4x2d", 4, 8, PipelineInner::TwoD, 4),
        ("pp8x3d", 8, 8, PipelineInner::ThreeD, 2),
        ("pp2x2.5d", 2, 8, PipelineInner::TwoFiveD { depth: 2 }, 4),
        ("pp2xdpx2d", 2, 8, PipelineInner::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 4),
    ];
    let mut entries = Vec::new();
    for (name, stages, m, inner, edge) in cases {
        let par = Parallelism::Pipeline { stages, micro_batches: m, inner };
        let world = par.world_size(edge);
        // One layer per stage; the unpipelined baseline is the same inner
        // mesh holding the whole stack (world/s ranks, s× the weights).
        let cfg = ModelConfig { layers: stages, ..ModelConfig::paper(4096, 64) };
        let t = time_core_step(&cfg, par, edge, net.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR8: {name} pipelined timing failed: {e}"));
        let flat = time_core_step(&cfg, inner.as_parallelism(), edge, net.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR8: {name} unpipelined timing failed: {e}"));
        let step = t.forward_s + t.backward_s;
        let flat_step = flat.forward_s + flat.backward_s;
        entries.push(format!(
            "    \"{name}\": {{ \"mesh\": \"{}\", \"world\": {world}, \
             \"stages\": {stages}, \"micro_batches\": {m}, \
             \"bubble_fraction\": {:.4}, \"step_virtual_s\": {step:.6}, \
             \"inner_unpipelined_step_s\": {flat_step:.6}, \
             \"comm_bytes_per_rank\": {} }}",
            par.mesh_desc(edge),
            pipeline_bubble_fraction(stages as u64, m as u64),
            t.metrics.total_bytes / world.max(1) as u64,
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR8.json");
    let json = format!(
        "{{\n  \"pr\": 8,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual-clock phantom mode; deterministic for a given NetModel\",\n  \
         \"model\": \"hidden 4096, batch 64, seq 512, 1 layer per stage (ModelConfig::paper)\",\n  \
         \"pipeline_phantom_step\": {{\n{}\n  }},\n  \
         \"note\": \"pipeline points at 64 ranks, 8 micro-batches, GPipe flush schedule. \
         bubble_fraction is the closed form (s-1)/(m+s-1); the costmodel tests pin the full \
         schedule recurrence bitwise against this engine clock under a dyadic network. \
         inner_unpipelined_step_s is the same inner mesh running all layers on world/s ranks \
         (s x the per-rank weight memory) — the memory-vs-bubble tradeoff the plan table \
         ranks. Numerics are bit-identical pipelined or not (tests/model_parity.rs).\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-7 headline numbers: fault-recovery cost. For every parallelism kind
/// at 64 ranks this trains a small real-numerics model twice — fault-free
/// vs a rank crashed mid-run and recovered (checkpoint restore, or replica
/// donation on the hybrid mesh) — and records the virtual-clock replay
/// overhead, plus the host-side cost of one checkpoint write/restore
/// round-trip. The recovered loss curve is asserted bit-identical to the
/// clean one before anything is written (the bench doubles as a pin).
fn write_json7() {
    use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
    use cubic::engine::{run_training_supervised, run_training_with_checkpoint};
    use cubic::topology::{HybridInner, Parallelism};
    use cubic::train::TrainerRank;
    // Smallest model that satisfies every kind's divisibility at 64 ranks
    // (1-D needs heads % 64 == 0; 3-D needs batch % 16 == 0).
    let model = ModelConfig {
        vocab: 64,
        hidden: 256,
        ffn: 1024,
        heads: 64,
        layers: 1,
        seq: 8,
        batch: 16,
        eps: 1e-5,
    };
    let cases: [(&str, Parallelism, usize); 6] = [
        ("seq", Parallelism::Seq, 1),
        ("1d", Parallelism::OneD, 64),
        ("2d", Parallelism::TwoD, 8),
        ("3d", Parallelism::ThreeD, 4),
        ("2.5d", Parallelism::TwoFiveD { depth: 4 }, 4),
        ("hybrid", Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 4),
    ];
    let net = cubic::comm::NetModel::longhorn_v100();
    let mut entries = Vec::new();
    for (name, par, edge) in cases {
        let world = par.world_size(edge);
        let cfg = CubicConfig {
            model: model.clone(),
            train: TrainConfig { steps: 3, warmup: 1, ckpt_every: 1, ..Default::default() },
            parallelism: par,
            edge,
            ..CubicConfig::default()
        };
        let dir = std::env::temp_dir().join(format!("cubic-bench7-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let clean = run_training_supervised(&cfg, net.clone(), None)
            .unwrap_or_else(|e| panic!("BENCH_PR7: {name} clean run failed: {e}"));
        let mut faulty_cfg = cfg.clone();
        faulty_cfg.faults.seed = 9;
        faulty_cfg.faults.crash = Some((world - 1, 2));
        let faulty = run_training_with_checkpoint(&faulty_cfg, net.clone(), &dir)
            .unwrap_or_else(|e| panic!("BENCH_PR7: {name} recovery failed: {e}"));
        assert_eq!(
            faulty.losses, clean.losses,
            "BENCH_PR7: {name} recovered run must be bit-identical"
        );
        // Host-side checkpoint round-trip on one rank's shard set.
        let trainer = TrainerRank::new(&cfg, 0);
        let t0 = std::time::Instant::now();
        trainer
            .save_checkpoint(&dir, 0, &[])
            .unwrap_or_else(|e| panic!("BENCH_PR7: {name} ckpt write failed: {e}"));
        let write_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let _ = TrainerRank::load_checkpoint(&cfg, 0, &dir)
            .unwrap_or_else(|e| panic!("BENCH_PR7: {name} ckpt restore failed: {e}"));
        let restore_ms = t1.elapsed().as_secs_f64() * 1e3;
        let _ = std::fs::remove_dir_all(&dir);
        entries.push(format!(
            "    \"{name}\": {{ \"mesh\": \"{}\", \"world\": {world}, \
             \"recoveries\": {}, \"step_virtual_s\": {:.6}, \
             \"recovery_overhead_virtual_s\": {:.6}, \
             \"ckpt_write_host_ms\": {write_ms:.3}, \"ckpt_restore_host_ms\": {restore_ms:.3} }}",
            par.mesh_desc(edge),
            faulty.recoveries,
            clean.metrics.virtual_time,
            faulty.metrics.virtual_time - clean.metrics.virtual_time,
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR7.json");
    let json = format!(
        "{{\n  \"pr\": 7,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual clock for overhead; wall-clock for ckpt write/restore\",\n  \
         \"model\": \"hidden 256, heads 64, batch 16, seq 8, 1 layer (real numerics, 3 steps)\",\n  \
         \"fault_recovery\": {{\n{}\n  }},\n  \
         \"note\": \"per-kind crash-at-step-2 recovery at 64 ranks with ckpt_every 1. \
         recovery_overhead_virtual_s = recovered-run virtual time minus fault-free virtual time \
         (generations chain on the clock, so the replayed steps are visible). hybrid recovers by \
         replica donation over comm; every other kind restores from the step-2 checkpoint. The \
         recovered loss curve is asserted bit-identical to the fault-free one.\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-5 headline numbers: phantom-mode step time and per-rank comm volume
/// for every parallelism kind at equal world size (64 ranks, paper-shape
/// model) — the cross-kind ranking the `plan --world` table prints,
/// persisted for the scheduled bench job's artifacts.
fn write_json5() {
    use cubic::config::ModelConfig;
    use cubic::engine::time_core_step;
    use cubic::topology::{HybridInner, Parallelism};
    let cfg = ModelConfig::paper(4096, 64);
    let net = cubic::comm::NetModel::longhorn_v100();
    let cases: [(&str, Parallelism, usize); 6] = [
        ("seq", Parallelism::Seq, 1),
        ("1d", Parallelism::OneD, 64),
        ("2d", Parallelism::TwoD, 8),
        ("3d", Parallelism::ThreeD, 4),
        ("2.5d", Parallelism::TwoFiveD { depth: 4 }, 4),
        ("hybrid", Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 4),
    ];
    let mut entries = Vec::new();
    for (name, par, edge) in cases {
        let world = par.world_size(edge);
        // Fail the bench loudly rather than uploading a stale JSON as a
        // "refreshed" artifact from the scheduled CI job.
        let t = time_core_step(&cfg, par, edge, net.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR5: {name} timing failed: {e}"));
        entries.push(format!(
            "    \"{name}\": {{ \"mesh\": \"{}\", \"world\": {world}, \
             \"step_virtual_s\": {:.6}, \"comm_bytes_per_rank\": {} }}",
            par.mesh_desc(edge),
            t.forward_s + t.backward_s,
            t.metrics.total_bytes / world.max(1) as u64,
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR5.json");
    let json = format!(
        "{{\n  \"pr\": 5,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual-clock phantom mode; deterministic for a given NetModel\",\n  \
         \"model\": \"hidden 4096, batch 64, seq 512, 1 layer (ModelConfig::paper)\",\n  \
         \"phantom_core_step\": {{\n{}\n  }},\n  \
         \"note\": \"per-kind phantom fwd+bwd virtual seconds and per-rank comm bytes at 64 \
         ranks (seq is the 1-device baseline). 2.5-D is 4x4x4 Tesseract, hybrid is 4 \
         data-parallel replicas around a 4x4 SUMMA grid; comm formulas are pinned against \
         this ledger by the costmodel tests.\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-6 headline numbers: compute/comm overlap on the virtual clock. For
/// every parallelism kind at 64 ranks this runs the phantom core step
/// twice — deferred grad syncs overlapped with compute vs the fully
/// serialized schedule — and records the speedup plus the fraction of comm
/// time that stayed exposed (on the critical path) under overlap. The
/// `overlap` field is set directly on the NetModel so the numbers are
/// independent of the CUBIC_OVERLAP env var.
fn write_json6() {
    use cubic::config::ModelConfig;
    use cubic::engine::time_core_step;
    use cubic::topology::{HybridInner, Parallelism};
    let cfg = ModelConfig::paper(4096, 64);
    let mut on = cubic::comm::NetModel::longhorn_v100();
    on.overlap = true;
    let mut off = on.clone();
    off.overlap = false;
    let cases: [(&str, Parallelism, usize); 6] = [
        ("seq", Parallelism::Seq, 1),
        ("1d", Parallelism::OneD, 64),
        ("2d", Parallelism::TwoD, 8),
        ("3d", Parallelism::ThreeD, 4),
        ("2.5d", Parallelism::TwoFiveD { depth: 4 }, 4),
        ("hybrid", Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 4),
    ];
    let mut entries = Vec::new();
    for (name, par, edge) in cases {
        let t_on = time_core_step(&cfg, par, edge, on.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR6: {name} overlapped timing failed: {e}"));
        let t_off = time_core_step(&cfg, par, edge, off.clone())
            .unwrap_or_else(|e| panic!("BENCH_PR6: {name} serialized timing failed: {e}"));
        let step_on = t_on.forward_s + t_on.backward_s;
        let step_off = t_off.forward_s + t_off.backward_s;
        let speedup = if step_on > 0.0 { step_off / step_on } else { 1.0 };
        // seq has no comm at all; guard the fraction's denominator.
        let comm = t_on.metrics.comm_time;
        let exposed_frac =
            if comm > 0.0 { t_on.metrics.exposed_comm_time / comm } else { 0.0 };
        entries.push(format!(
            "    \"{name}\": {{ \"mesh\": \"{}\", \
             \"step_overlapped_s\": {step_on:.6}, \"step_serialized_s\": {step_off:.6}, \
             \"overlap_speedup\": {speedup:.4}, \"exposed_comm_fraction\": {exposed_frac:.4} }}",
            par.mesh_desc(edge),
        ));
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR6.json");
    let json = format!(
        "{{\n  \"pr\": 6,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"virtual-clock phantom mode; deterministic for a given NetModel\",\n  \
         \"model\": \"hidden 4096, batch 64, seq 512, 1 layer (ModelConfig::paper)\",\n  \
         \"phantom_overlap_step\": {{\n{}\n  }},\n  \
         \"note\": \"per-kind phantom core step at 64 ranks, deferred-collective overlap vs the \
         serialized schedule (numerics are bit-identical either way; only the clock moves). \
         overlap_speedup = serialized / overlapped step time; exposed_comm_fraction = exposed / \
         total comm time under overlap. hybrid is the kind with a hideable boundary (replica \
         grad all-reduces drained behind the next layer's backward GEMMs), so it shows the \
         headline win; kinds whose collectives sit on the critical path stay near 1.0x.\"\n}}\n",
        entries.join(",\n"),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// PR-3 headline numbers: the threaded-over-serial driver ratio at 256³
/// plus the pool counters proving the collective steady state stayed
/// allocation-free with the threaded driver in the process.
fn write_json3(serial_gf: f64, threaded_gf: f64, ar_misses: u64, pack_bytes_per_flop: f64) {
    let t = kernel::threads::selected_threads();
    let ratio = if serial_gf > 0.0 { threaded_gf / serial_gf } else { 0.0 };
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR3.json");
    let json = format!(
        "{{\n  \"pr\": 3,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"wall-clock on the build host; regenerate locally for comparable numbers\",\n  \
         \"kernel_selected\": \"{}\",\n  \
         \"threads_selected\": {t},\n  \
         \"matmul_256_gflops\": {{ \"serial_1t\": {serial_gf:.3}, \"threaded\": {threaded_gf:.3} }},\n  \
         \"threads_over_serial\": {ratio:.2},\n  \
         \"gemm_pool\": {{ \"threaded_jobs\": {}, \"serial_fallbacks\": {} }},\n  \
         \"gemm_pack_bytes_per_flop\": {pack_bytes_per_flop:.4},\n  \
         \"all_reduce_pool_misses_after_warmup\": {ar_misses},\n  \
         \"note\": \"threads_over_serial is best-of-3 at 256^3 through the dispatched kernel; asserted >= 0.95 in full runs and >= 0.80 in --smoke (CI shared-runner noise band). Bit-exactness across thread counts is pinned by tests/kernel_threads.rs, and the tree-reduce/broadcast_bw/reduce_bw pool extensions by the collectives tests.\"\n}}\n",
        kernel::selected_name(),
        kernel::threads::threaded_jobs(),
        kernel::threads::serial_fallbacks(),
    );
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
