//! Host-side performance microbenches (§Perf of EXPERIMENTS.md): wall-clock
//! throughput of the hot paths — the blocked matmul kernels, the collective
//! engine, and the phantom-mode scheduling overhead that bounds how fast
//! the table benches can sweep configurations.
//!
//! Run: `cargo bench --bench microbench`

use cubic::collectives::all_reduce;
use cubic::comm::NetModel;
use cubic::metrics::Stopwatch;
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::{matmul_flops, Tensor};

fn bench_matmul(label: &str, m: usize, k: usize, n: usize, iters: usize) {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    // Warm-up.
    let mut sink = a.matmul(&b).at2(0, 0);
    let sw = Stopwatch::start();
    for _ in 0..iters {
        sink += a.matmul(&b).at2(0, 0);
    }
    let secs = sw.seconds();
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!("matmul_nn {label}: {gflops:.2} GF/s  ({:.3} ms/iter, sink {sink:.1})", 1e3 * secs / iters as f64);
}

fn bench_matmul_nt(m: usize, k: usize, n: usize, iters: usize) {
    let mut rng = Xoshiro256::seed_from_u64(2);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[n, k], 1.0, &mut rng);
    let mut sink = 0.0;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        sink += a.matmul_nt(&b).at2(0, 0);
    }
    let secs = sw.seconds();
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!("matmul_nt {m}x{k}x{n}: {gflops:.2} GF/s (sink {sink:.1})");
}

fn bench_collectives(world: usize, elems: usize, iters: usize) {
    let sw = Stopwatch::start();
    let its = iters;
    run_spmd(world, NetModel::zero(), move |rank, ep| {
        let group: Vec<usize> = (0..world).collect();
        let t = Tensor::full(&[elems], rank as f32);
        for _ in 0..its {
            let _ = all_reduce(ep, &group, &t);
        }
    });
    let secs = sw.seconds();
    let gb = (iters * world * elems * 4) as f64 / 1e9;
    println!(
        "all_reduce world={world} n={elems}: {:.3} ms/op, {:.2} GB/s aggregate",
        1e3 * secs / iters as f64,
        gb / secs
    );
}

fn bench_phantom_overhead() {
    // Per-op cost of the phantom scheduling path: 8-rank 3-D matmul.
    use cubic::dist::Dirs;
    use cubic::parallel::threed::{mm_nn, Ctx3D};
    use cubic::topology::Cube;
    let iters = 200usize;
    let sw = Stopwatch::start();
    run_spmd(8, NetModel::longhorn_v100(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(2), rank);
        let a = Tensor::phantom(&[1024, 2048]);
        let b = Tensor::phantom(&[2048, 1024]);
        for _ in 0..iters {
            let _ = mm_nn(ep, &ctx, &a, &b, Dirs::canonical());
        }
    });
    let secs = sw.seconds();
    println!(
        "phantom mm_nn (8 ranks): {:.1} µs/op/rank",
        1e6 * secs / iters as f64
    );
}

fn main() {
    println!("## Host microbenchmarks (wall-clock)\n");
    cubic::tensor::reset_flop_counter();
    bench_matmul("256x256x256", 256, 256, 256, 20);
    bench_matmul("512x512x512", 512, 512, 512, 4);
    bench_matmul("128x1024x128", 128, 1024, 128, 20);
    bench_matmul_nt(256, 256, 256, 20);
    bench_collectives(4, 1 << 16, 50);
    bench_collectives(8, 1 << 16, 50);
    bench_phantom_overhead();
    let _ = matmul_flops();
}
