//! Host-side performance microbenches (§Perf of EXPERIMENTS.md): wall-clock
//! throughput of the hot paths — the blocked matmul kernels, the collective
//! engine, and the phantom-mode scheduling overhead that bounds how fast
//! the table benches can sweep configurations.
//!
//! Since the Arc-backed storage refactor this bench also reports **bytes
//! cloned** (the copy-on-write counter in `cubic::metrics`) next to GF/s:
//! the send path of the transport must contribute exactly 0, and a ring
//! all-reduce's only clone is the one accumulator materialization per rank
//! per call (numel/g floats), independent of ring length.
//!
//! Run: `cargo bench --bench microbench`
//! Side effect: rewrites `BENCH_PR1.json` at the repo root with the
//! headline numbers (256³ matmul GF/s, 8-rank all-reduce clone/op stats).

use cubic::collectives::all_reduce;
use cubic::comm::{NetModel, World};
use cubic::metrics::{bytes_cloned, Stopwatch};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::{matmul_flops, Tensor};

fn bench_matmul(label: &str, m: usize, k: usize, n: usize, iters: usize) -> f64 {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    // Warm-up.
    let mut sink = a.matmul(&b).at2(0, 0);
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    for _ in 0..iters {
        sink += a.matmul(&b).at2(0, 0);
    }
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!(
        "matmul_nn {label}: {gflops:.2} GF/s  ({:.3} ms/iter, {cloned} B cloned, sink {sink:.1})",
        1e3 * secs / iters as f64
    );
    gflops
}

fn bench_matmul_nt(m: usize, k: usize, n: usize, iters: usize) -> f64 {
    let mut rng = Xoshiro256::seed_from_u64(2);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[n, k], 1.0, &mut rng);
    let mut sink = 0.0;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        sink += a.matmul_nt(&b).at2(0, 0);
    }
    let secs = sw.seconds();
    let gflops = (iters as f64 * 2.0 * m as f64 * k as f64 * n as f64) / secs / 1e9;
    println!("matmul_nt {m}x{k}x{n}: {gflops:.2} GF/s (sink {sink:.1})");
    gflops
}

/// Pure transport benchmark: the send path must never copy payload data.
/// Returns the (exactly measured) bytes cloned by N sends of a large
/// tensor — the acceptance number for the zero-copy refactor.
fn bench_send_path(elems: usize, iters: usize) -> u64 {
    let mut world = World::new(2, NetModel::zero());
    let mut e0 = world.endpoint(0);
    let mut e1 = world.endpoint(1);
    let payload = Tensor::full(&[elems], 1.0);
    let its = iters as u64;
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    let h = std::thread::spawn(move || {
        for i in 0..its {
            e0.send(1, i, &payload);
        }
    });
    for i in 0..its {
        let got = e1.recv(0, i);
        assert_eq!(got.numel(), elems);
    }
    h.join().unwrap();
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    println!(
        "send path: {iters} x {} KiB messages in {:.3} ms — {cloned} B cloned (expect 0)",
        elems * 4 / 1024,
        1e3 * secs
    );
    cloned
}

/// 8-rank materialized ring all-reduce: ms/op plus cloned bytes per rank
/// per op (the steady-state allocation figure).
fn bench_collectives(world: usize, elems: usize, iters: usize) -> (f64, f64) {
    let cloned0 = bytes_cloned();
    let sw = Stopwatch::start();
    let its = iters;
    run_spmd(world, NetModel::zero(), move |rank, ep| {
        let group: Vec<usize> = (0..world).collect();
        let t = Tensor::full(&[elems], rank as f32);
        for _ in 0..its {
            let _ = all_reduce(ep, &group, &t);
        }
    });
    let secs = sw.seconds();
    let cloned = bytes_cloned() - cloned0;
    let cloned_per_rank_op = cloned as f64 / (world * iters) as f64;
    let gb = (iters * world * elems * 4) as f64 / 1e9;
    println!(
        "all_reduce world={world} n={elems}: {:.3} ms/op, {:.2} GB/s aggregate, \
         {cloned_per_rank_op:.0} B cloned/rank/op (chunk = {} B)",
        1e3 * secs / iters as f64,
        gb / secs,
        elems / world * 4,
    );
    (1e3 * secs / iters as f64, cloned_per_rank_op)
}

fn bench_phantom_overhead() {
    // Per-op cost of the phantom scheduling path: 8-rank 3-D matmul.
    use cubic::dist::Dirs;
    use cubic::parallel::threed::{mm_nn, Ctx3D};
    use cubic::topology::Cube;
    let iters = 200usize;
    let sw = Stopwatch::start();
    run_spmd(8, NetModel::longhorn_v100(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(2), rank);
        let a = Tensor::phantom(&[1024, 2048]);
        let b = Tensor::phantom(&[2048, 1024]);
        for _ in 0..iters {
            let _ = mm_nn(ep, &ctx, &a, &b, Dirs::canonical());
        }
    });
    let secs = sw.seconds();
    println!(
        "phantom mm_nn (8 ranks): {:.1} µs/op/rank",
        1e6 * secs / iters as f64
    );
}

fn write_json(
    nn256: f64,
    nt256: f64,
    send_cloned: u64,
    ar_ms: f64,
    ar_cloned_per_rank_op: f64,
) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR1.json");
    let json = format!(
        "{{\n  \"pr\": 1,\n  \"generated_by\": \"cargo bench --bench microbench\",\n  \
         \"host\": \"wall-clock on the build host; regenerate locally for comparable numbers\",\n  \
         \"matmul_nn_256\": {{ \"gflops\": {nn256:.3} }},\n  \
         \"matmul_nt_256\": {{ \"gflops\": {nt256:.3} }},\n  \
         \"send_path_bytes_cloned\": {send_cloned},\n  \
         \"all_reduce_8rank_65536\": {{\n    \"ms_per_op\": {ar_ms:.4},\n    \
         \"bytes_cloned_per_rank_per_op\": {ar_cloned_per_rank_op:.1},\n    \
         \"note\": \"pre-refactor transport deep-copied every payload: >= 2*(g-1)/g*n bytes per rank per op on the ring, plus per-hop chunk clones\"\n  }}\n}}\n"
    );
    match std::fs::write(path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

fn main() {
    println!("## Host microbenchmarks (wall-clock)\n");
    cubic::tensor::reset_flop_counter();
    let nn256 = bench_matmul("256x256x256", 256, 256, 256, 20);
    bench_matmul("512x512x512", 512, 512, 512, 4);
    bench_matmul("128x1024x128", 128, 1024, 128, 20);
    let nt256 = bench_matmul_nt(256, 256, 256, 20);
    let send_cloned = bench_send_path(1 << 18, 100);
    assert_eq!(send_cloned, 0, "transport send path must be zero-copy");
    bench_collectives(4, 1 << 16, 50);
    let (ar_ms, ar_cloned) = bench_collectives(8, 1 << 16, 50);
    // Exact pin (this process owns the counter): the ONLY clone per rank
    // per all-reduce is the step-0 accumulator materialization of one
    // chunk. Any reintroduced per-hop copy fails this equality.
    let chunk_bytes = ((1usize << 16) / 8 * 4) as f64;
    assert_eq!(
        ar_cloned, chunk_bytes,
        "8-rank all-reduce must clone exactly one chunk per rank per op"
    );
    bench_phantom_overhead();
    let _ = matmul_flops();
    write_json(nn256, nt256, send_cloned, ar_ms, ar_cloned);
}
