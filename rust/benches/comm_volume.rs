//! Ablation bench for the paper's §3.1.2 bandwidth/latency claims: per-rank
//! communication volume of one transformer layer (fwd+bwd) under each
//! parallelism as P grows — measured from the engine's traffic ledger, not
//! computed from formulas (the formulas are unit-tested against the ledger
//! in `costmodel`).
//!
//! Expected shape: 1-D volume is ~flat in P (all-reduces of full
//! activations); 2-D shrinks ~1/q; 3-D shrinks ~1/p² = O(P^{-2/3}).
//!
//! Run: `cargo bench --bench comm_volume`

use cubic::comm::NetModel;
use cubic::config::ModelConfig;
use cubic::engine::time_core_step;
use cubic::metrics::{fmt_bytes, Table};
use cubic::topology::{HybridInner, Parallelism};

fn main() {
    let mut t = Table::new(&[
        "Approach", "# GPUs", "bytes/rank (fwd+bwd)", "inter-node share", "latency (msgs/rank)",
    ]);
    let cfg = ModelConfig { layers: 1, ..ModelConfig::paper(4096, 16) };
    let cases = [
        (Parallelism::OneD, 8usize),
        (Parallelism::OneD, 64),
        (Parallelism::TwoD, 3), // 9 GPUs
        (Parallelism::TwoD, 8), // 64
        (Parallelism::ThreeD, 2), // 8
        (Parallelism::ThreeD, 4), // 64
        (Parallelism::TwoFiveD { depth: 2 }, 2), // 8: between 2-D and 3-D
        (Parallelism::TwoFiveD { depth: 4 }, 4), // 64
        (Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 2), // 8
        (Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 4), // 64
    ];
    for (par, edge) in cases {
        let world = par.world_size(edge);
        let timing = time_core_step(&cfg, par, edge, NetModel::longhorn_v100()).unwrap();
        let per_rank = timing.metrics.total_bytes / world as u64;
        let inter = timing.metrics.inter_node_bytes as f64
            / timing.metrics.total_bytes.max(1) as f64;
        t.row(&[
            par.name().to_string(),
            world.to_string(),
            fmt_bytes(per_rank),
            format!("{:.0}%", 100.0 * inter),
            (timing.metrics.messages / world as u64).to_string(),
        ]);
    }
    println!("## §3.1.2 — per-rank communication volume, one layer fwd+bwd\n");
    println!("{}", t.to_markdown());
    println!("\nPaper claims: 3-D bandwidth O(P^-2/3), latency O(log p); 1-D volume flat in P.");
    // Phantom-mode runs move no data at all: the copy-on-write counter must
    // stay at zero across every sweep above.
    let cloned = cubic::metrics::bytes_cloned();
    assert_eq!(cloned, 0, "phantom sweeps must not clone tensor data");
    println!("bytes cloned across all sweeps: {cloned} (phantom mode is data-free)");
}
