//! Offline stand-in for the `anyhow` crate.
//!
//! The container's crate set has no registry access, so `cubic` vendors the
//! small `anyhow` subset it actually uses: a string-backed [`Error`], the
//! [`Result`] alias, the `anyhow!` / `bail!` / `ensure!` macros, the
//! [`Context`] extension trait, and a blanket `From` impl so `?` converts
//! any `std::error::Error`. API-compatible for these uses with the real
//! crate, so swapping the dependency back is a Cargo.toml change only.

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error` — that keeps the
/// blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context<C: fmt::Display>(mut self, c: C) -> Error {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, like anyhow's single-line format.
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// (and options), converting the error into [`Error`] with a prefix.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes_outermost_first() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r2: std::result::Result<(), String> = Err("inner".into());
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e2.to_string(), "step 3: inner");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn option_context() {
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}
