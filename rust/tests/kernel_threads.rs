//! Concurrency battery for the multi-core GEMM driver (PR 3).
//!
//! * **Thread-count parity** — for thread counts {1, 2, 3, 4, 8} and all
//!   three matmul forms (nn/nt/tn), the driver's output must be
//!   *bit-identical* to the single-threaded run: across the PR-2 edge-dim
//!   sweep (m, n, k ∈ 1..=17), the 63/64/65 cache-block boundary, the
//!   multi-k-block path (k > KC), and the 256³ headline shape. This is the
//!   property that keeps the PR-1/PR-2 parity suites meaningful on
//!   multi-core hosts: threading may change *where* a tile is computed,
//!   never its bits.
//! * **Flop exactness** — concurrent gemms must report exactly the serial
//!   flop total (per-thread tallies merged on completion, no lost or
//!   duplicated counts).
//! * **Buffer-pool stress** — threads hammering acquire/drop cycles on one
//!   shared pool must never double-reclaim a buffer, and the multi-threaded
//!   all-reduce steady state must stay allocation-free with the threaded
//!   gemm driver running beside it (the acceptance pin of this PR).

use cubic::comm::pool::{BufferPool, Takeout};
use cubic::comm::NetModel;
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::kernel::{self, gemm_strided_t, Kernel, JC_STRIPE, KC, NC};
use cubic::tensor::Tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Thread counts the battery sweeps: 1 (the serial baseline itself), the
/// plausible host counts, and 8 (more participants than most CI cores, so
/// oversubscription is covered too).
const THREAD_COUNTS: [usize; 5] = [1, 2, 3, 4, 8];

fn fill(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// The three forms as pack strides over row-major storage (same mapping as
/// tests/kernel_parity.rs).
#[derive(Clone, Copy)]
enum Form {
    Nn,
    Nt,
    Tn,
}

impl Form {
    fn name(self) -> &'static str {
        match self {
            Form::Nn => "nn",
            Form::Nt => "nt",
            Form::Tn => "tn",
        }
    }

    /// ((a_len, ars, aks), (b_len, brs, bcs)) for logical (m,k)·(k,n).
    #[allow(clippy::type_complexity)]
    fn strides(
        self,
        m: usize,
        n: usize,
        k: usize,
    ) -> ((usize, usize, usize), (usize, usize, usize)) {
        match self {
            Form::Nn => ((m * k, k, 1), (k * n, n, 1)),
            Form::Nt => ((m * k, k, 1), (n * k, 1, k)),
            Form::Tn => ((k * m, 1, m), (k * n, n, 1)),
        }
    }
}

/// Run one shape through every thread count and assert bit-parity with the
/// single-threaded output (and exact flop tallies everywhere).
fn check_parity(kern: Kernel, form: Form, m: usize, n: usize, k: usize) {
    let ((alen, ars, aks), (blen, brs, bcs)) = form.strides(m, n, k);
    let a = fill(9000 + (m * 37 + n * 11 + k) as u64, alen);
    let b = fill(800 + (m + n * 17 + k * 3) as u64, blen);
    let mut base = vec![0.0f32; m * n];
    let serial_flops = gemm_strided_t(kern, 1, m, n, k, &a, ars, aks, &b, brs, bcs, &mut base);
    assert_eq!(serial_flops, 2 * (m * n * k) as u64, "{} ({m},{n},{k})", form.name());
    for &t in &THREAD_COUNTS[1..] {
        let mut c = vec![0.0f32; m * n];
        let flops = gemm_strided_t(kern, t, m, n, k, &a, ars, aks, &b, brs, bcs, &mut c);
        assert_eq!(
            flops,
            serial_flops,
            "{} ({m},{n},{k}) t={t}: merged flops must equal serial",
            form.name()
        );
        // Bitwise: any FP reassociation across threads fails here.
        assert_eq!(c, base, "{} ({m},{n},{k}) t={t}: output must be bit-exact", form.name());
    }
}

#[test]
fn thread_parity_edge_dim_sweep_all_forms() {
    // The PR-2 edge-dim sweep (every microkernel-tile remainder geometry),
    // re-run per thread count. Small shapes clamp participants to the strip
    // count, so this also covers threads > strips.
    let kern = kernel::selected();
    for form in [Form::Nn, Form::Nt, Form::Tn] {
        for m in 1..=17 {
            for n in 1..=17 {
                for k in 1..=17 {
                    check_parity(kern, form, m, n, k);
                }
            }
        }
    }
}

#[test]
fn thread_parity_cache_block_boundaries_all_forms() {
    let kern = kernel::selected();
    let boundary = [63usize, 64, 65];
    for form in [Form::Nn, Form::Nt, Form::Tn] {
        for &m in &boundary {
            for &n in &boundary {
                for &k in &boundary {
                    check_parity(kern, form, m, n, k);
                }
            }
        }
        // k > KC: the C += per-k-block accumulation path — the geometry
        // where out-of-order k-blocks would first break bit-parity.
        // (Explicit-count calls have no size threshold, so threads engage
        // whenever the pool is free — threaded_jobs_actually_ran guards
        // against that coverage silently vanishing.)
        check_parity(kern, form, 65, 33, KC + 41);
        check_parity(kern, form, 97, 129, 2 * KC + 37);
    }
}

#[test]
fn thread_parity_256_cube_all_forms() {
    let kern = kernel::selected();
    for form in [Form::Nn, Form::Nt, Form::Tn] {
        check_parity(kern, form, 256, 256, 256);
    }
}

#[test]
fn thread_parity_wide_n_short_m_all_forms() {
    // The jc-parallel geometry (ROADMAP follow-on): few (or one) MR row
    // strips but many NC blocks, so all the parallelism comes from the
    // block axis of the tile claims. Includes an n that crosses a stripe
    // boundary with a ragged tail, and k > KC for the multi-k-block
    // accumulation order.
    let kern = kernel::selected();
    for form in [Form::Nn, Form::Nt, Form::Tn] {
        check_parity(kern, form, 8, 4 * NC, 64); // one strip, four blocks
        check_parity(kern, form, 1, 3 * NC + 5, 33); // single-row, ragged block
        check_parity(kern, form, 16, 2 * NC + 7, KC + 3);
    }
    // Stripe-boundary crossing: n > JC_STRIPE forces two (stripe, pc)
    // phases with a ragged second stripe. One form keeps the sweep cheap.
    check_parity(kern, Form::Nn, 4, JC_STRIPE + NC + 5, 17);
}

#[test]
fn wide_n_short_m_engages_threads() {
    // m = 8 is a single MR strip: the pre-stripe driver clamped this shape
    // to one participant and always ran serial. Tile claims must now put
    // it on the pool whenever the pool is free.
    let kern = kernel::selected();
    let (m, n, k) = (8, 8 * NC, 128);
    let a = fill(31, m * k);
    let b = fill(32, k * n);
    let mut base = vec![0.0f32; m * n];
    gemm_strided_t(kern, 1, m, n, k, &a, k, 1, &b, n, 1, &mut base);
    let mut ok = false;
    for _ in 0..50 {
        let before = kernel::threads::threaded_jobs();
        let mut c = vec![0.0f32; m * n];
        gemm_strided_t(kern, 4, m, n, k, &a, k, 1, &b, n, 1, &mut c);
        assert_eq!(c, base, "threaded wide-n run must stay bit-exact");
        if kernel::threads::threaded_jobs() > before {
            ok = true;
            break;
        }
    }
    assert!(ok, "wide-n/short-m gemm never ran threaded — jc parallelism is broken");
}

#[test]
fn threaded_jobs_actually_ran() {
    // Guard against coverage rot: a large gemm with an explicit thread
    // count must actually execute on the pool (not silently fall back)
    // when the pool is uncontended. Retry a few times in case concurrent
    // battery tests hold the pool at first.
    let kern = kernel::selected();
    let (m, n, k) = (256, 128, 128);
    let a = fill(1, m * k);
    let b = fill(2, k * n);
    let mut ok = false;
    for _ in 0..50 {
        let before = kernel::threads::threaded_jobs();
        let mut c = vec![0.0f32; m * n];
        gemm_strided_t(kern, 2, m, n, k, &a, k, 1, &b, n, 1, &mut c);
        if kernel::threads::threaded_jobs() > before {
            ok = true;
            break;
        }
    }
    assert!(ok, "no threaded job ran in 50 attempts — pool wiring is broken");
}

#[test]
fn concurrent_gemms_report_exact_serial_flop_totals() {
    // Four caller threads, each running several threaded gemms: every call
    // must return exactly 2·m·n·k (merged per-thread tallies), and the
    // global counter must have advanced by at least the sum. Fair-share
    // leasing usually gives every caller a slice of the pool, but a
    // saturated pool still yields empty-lease serial fallbacks — both
    // paths must count identically.
    let kern = kernel::selected();
    let (m, n, k) = (128, 96, 64);
    let per_call = 2 * (m * n * k) as u64;
    let calls_per_thread = 3u64;
    let before = cubic::tensor::matmul_flops();
    let handles: Vec<_> = (0..4u64)
        .map(|t| {
            std::thread::spawn(move || {
                let a = fill(100 + t, m * k);
                let b = fill(200 + t, k * n);
                let mut sum = 0u64;
                for _ in 0..calls_per_thread {
                    let mut c = vec![0.0f32; m * n];
                    sum += gemm_strided_t(kern, 3, m, n, k, &a, k, 1, &b, n, 1, &mut c);
                }
                sum
            })
        })
        .collect();
    let mut total = 0u64;
    for h in handles {
        let sum = h.join().unwrap();
        assert_eq!(sum, calls_per_thread * per_call, "per-caller tallies must be exact");
        total += sum;
    }
    // Other tests in this binary may add flops concurrently, never remove.
    assert!(cubic::tensor::matmul_flops() - before >= total);
}

#[test]
fn concurrent_callers_both_lease_workers() {
    // Fair-share leasing (the ROADMAP housekeeping item this PR closes):
    // two callers issuing threaded gemms at the same instant must BOTH run
    // on pool workers — the pool splits its worker budget between jobs in
    // flight instead of handing the whole pool to the first caller and
    // dropping the second to the serial fallback. Each round gates both
    // gemms between barriers so they overlap, reading the threaded-job
    // counter before either starts and after both finish; one round in 50
    // where the counter advanced by two proves the split. Bit-exactness
    // is asserted every round regardless, because a lease of any size
    // (including the empty-lease serial fallback) computes identical bits.
    let kern = kernel::selected();
    let (m, n, k) = (256, 128, 128);
    let rounds = 50usize;
    let barrier = Arc::new(std::sync::Barrier::new(2));
    let both_threaded = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..2u64)
        .map(|t| {
            let barrier = Arc::clone(&barrier);
            let both_threaded = Arc::clone(&both_threaded);
            std::thread::spawn(move || {
                let a = fill(300 + t, m * k);
                let b = fill(400 + t, k * n);
                let mut base = vec![0.0f32; m * n];
                gemm_strided_t(kern, 1, m, n, k, &a, k, 1, &b, n, 1, &mut base);
                for _ in 0..rounds {
                    barrier.wait();
                    let before = kernel::threads::threaded_jobs();
                    // Second barrier: neither gemm starts until both callers
                    // have read `before`, so neither read can miss the other
                    // caller's increment.
                    barrier.wait();
                    let mut c = vec![0.0f32; m * n];
                    gemm_strided_t(kern, 4, m, n, k, &a, k, 1, &b, n, 1, &mut c);
                    assert_eq!(c, base, "caller {t}: concurrent gemm must stay bit-exact");
                    barrier.wait();
                    if kernel::threads::threaded_jobs() - before >= 2 {
                        both_threaded.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        both_threaded.load(Ordering::Relaxed) > 0,
        "two concurrent callers never both ran threaded in {rounds} rounds — \
         the fair-share worker split is broken (one caller hogs the pool)"
    );
}

#[test]
fn concurrent_decode_and_prefill_callers_both_lease_workers() {
    // The serving shape of the fair-share property (PR 9): a continuous
    // batching engine keeps a latency-critical decode step (one row per
    // slot — short m, wide n, all jc parallelism) in flight while a bulky
    // prefill gemm for a newly admitted request runs beside it. Both
    // callers must lease workers in the same round: if the prefill job
    // could hog the pool, decode latency would absorb the whole prefill
    // instead of sharing the budget. Same barrier/counter protocol as
    // concurrent_callers_both_lease_workers, with the two callers running
    // *different* shapes.
    let kern = kernel::selected();
    let shapes = [(8usize, 8 * NC, 128usize), (256, 128, 128)]; // decode, prefill
    let rounds = 50usize;
    let barrier = Arc::new(std::sync::Barrier::new(2));
    let both_threaded = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..2usize)
        .map(|t| {
            let barrier = Arc::clone(&barrier);
            let both_threaded = Arc::clone(&both_threaded);
            std::thread::spawn(move || {
                let (m, n, k) = shapes[t];
                let a = fill(500 + t as u64, m * k);
                let b = fill(600 + t as u64, k * n);
                let mut base = vec![0.0f32; m * n];
                gemm_strided_t(kern, 1, m, n, k, &a, k, 1, &b, n, 1, &mut base);
                for _ in 0..rounds {
                    barrier.wait();
                    let before = kernel::threads::threaded_jobs();
                    barrier.wait();
                    let mut c = vec![0.0f32; m * n];
                    gemm_strided_t(kern, 4, m, n, k, &a, k, 1, &b, n, 1, &mut c);
                    assert_eq!(
                        c, base,
                        "caller {t} ({m}x{n}x{k}): concurrent gemm must stay bit-exact"
                    );
                    barrier.wait();
                    if kernel::threads::threaded_jobs() - before >= 2 {
                        both_threaded.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        both_threaded.load(Ordering::Relaxed) > 0,
        "decode- and prefill-shaped callers never both ran threaded in {rounds} rounds — \
         the fair-share split must hold for asymmetric job shapes too"
    );
}

#[test]
fn buffer_pool_survives_concurrent_acquire_drop_hammering() {
    // N threads share one BufferPool and hammer acquire/write/verify/drop
    // cycles. Invariants under the storm:
    //   * every buffer is owned by exactly one tensor at a time (the
    //     write/verify pattern catches aliasing from a double-reclaim);
    //   * after joining, the free list holds exactly the buffers that were
    //     ever allocated — a double-reclaim would leave idle > allocated;
    //   * at most N buffers are ever allocated (a take() only allocates
    //     when the free list is empty, and at most N are in flight).
    let nthreads = 8usize;
    let cycles = 2000usize;
    let elems = 256usize;
    let pool = Arc::new(BufferPool::new());
    let allocs = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..nthreads)
        .map(|tid| {
            let pool = pool.clone();
            let allocs = allocs.clone();
            std::thread::spawn(move || {
                for i in 0..cycles {
                    let (mut t, how) = pool.tensor(&[elems]);
                    if how == Takeout::Allocated {
                        allocs.fetch_add(1, Ordering::Relaxed);
                    }
                    let stamp = (tid * cycles + i) as f32;
                    t.data_mut().fill(stamp);
                    assert_eq!(t.data()[0], stamp, "aliased buffer: another owner wrote");
                    assert_eq!(t.data()[elems - 1], stamp);
                    drop(t);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let allocated = allocs.load(Ordering::Relaxed);
    assert!(allocated <= nthreads, "allocations ({allocated}) cannot exceed peak in-flight");
    assert_eq!(
        pool.idle(),
        allocated,
        "every allocated buffer must be parked exactly once (no double-reclaim, no leak)"
    );
}

#[test]
fn all_reduce_steady_state_zero_alloc_with_threaded_gemm() {
    // The acceptance pin: a steady-state all-reduce performs 0 buffer
    // allocations per rank per call *while the threaded gemm driver is
    // doing real matmuls on the same ranks* — the shape every training step
    // has. The matmul is large enough to engage the pool (ranks contend for
    // it; losers take the bit-identical serial fallback), and its output is
    // asserted bit-stable across iterations, so determinism under pool
    // contention is covered by the same test.
    let world = 4usize;
    let dim = 128usize;
    let iters = 4u64;
    let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
        let group: Vec<usize> = (0..world).collect();
        let mut rng = Xoshiro256::seed_from_u64(rank as u64 + 1);
        let a = Tensor::randn(&[dim, dim], 1.0, &mut rng);
        let b = Tensor::randn(&[dim, dim], 1.0, &mut rng);
        // Warmup: populates the recycling pool; baseline for bit-stability.
        let c0 = a.matmul(&b);
        let r0 = cubic::collectives::all_reduce(ep, &group, &c0);
        let baseline_local = c0.data().to_vec();
        let baseline_sum = r0.data().to_vec();
        drop(r0);
        ep.barrier_wait();
        let m0 = ep.stats.pool_misses;
        for _ in 0..iters {
            let c = a.matmul(&b);
            assert_eq!(c.data(), &baseline_local[..], "rank {rank}: matmul must be bit-stable");
            let r = cubic::collectives::all_reduce(ep, &group, &c);
            assert_eq!(r.data(), &baseline_sum[..], "rank {rank}: reduced sum must be bit-stable");
            drop(r);
            ep.barrier_wait();
        }
        ep.stats.pool_misses - m0
    });
    for (rank, misses) in out.iter().enumerate() {
        assert_eq!(
            *misses, 0,
            "rank {rank}: steady-state all-reduce must stay allocation-free with threads on"
        );
    }
}
