//! Integration: distributed matmuls vs dense references across all
//! parallelisms, matmul forms and direction triples — the shard-for-shard
//! correctness net under the paper's Algorithms 1–6 and the SUMMA/Megatron
//! baselines.

use cubic::comm::NetModel;
use cubic::dist::{Dirs, Layout1D, Layout2D, Layout3D};
use cubic::parallel::threed::{self, Ctx3D, Layout3DExt};
use cubic::parallel::{oned, twod};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{Axis, Cube, Mesh};

fn randt(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Tensor::randn(shape, 1.0, &mut rng)
}

/// Every distinct direction triple (3! = 6 permutations of the axes).
fn all_dirs() -> Vec<Dirs> {
    let axes = [Axis::X, Axis::Y, Axis::Z];
    let mut out = Vec::new();
    for &a in &axes {
        for &b in &axes {
            for &c in &axes {
                if a != b && b != c && a != c {
                    out.push(Dirs { a, b, c });
                }
            }
        }
    }
    out
}

#[test]
fn threed_mm_nn_all_direction_triples() {
    let p = 2;
    let cube = Cube::new(p);
    let (m, n, k) = (8, 12, 16);
    let a = randt(&[m, n], 1);
    let b = randt(&[n, k], 2);
    let c_ref = a.matmul(&b);
    for dirs in all_dirs() {
        let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
        let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
        let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            threed::mm_nn(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
        });
        let got = Layout3D::output(dirs).gather(&cube, &out, m, k);
        assert!(got.max_abs_diff(&c_ref) < 1e-3, "dirs {dirs:?}");
    }
}

#[test]
fn threed_mm_nn_p3_cube_27_ranks() {
    // A non-power-of-two cube edge exercises ring steps and uneven trees.
    let p = 3;
    let cube = Cube::new(p);
    let dirs = Dirs::canonical();
    let (m, n, k) = (18, 9, 27);
    let a = randt(&[m, n], 3);
    let b = randt(&[n, k], 4);
    let c_ref = a.matmul(&b);
    let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
    let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
    let out = run_spmd(27, NetModel::zero(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(p), rank);
        threed::mm_nn(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
    });
    let got = Layout3D::output(dirs).gather(&cube, &out, m, k);
    assert!(got.max_abs_diff(&c_ref) < 1e-3);
}

#[test]
fn threed_chained_linears_swap_directions() {
    // Two chained mm_nn calls with swapped dirs — the §3.2 stacking
    // pattern: output of layer 1 feeds layer 2 unchanged.
    let p = 2;
    let cube = Cube::new(p);
    let d0 = Dirs::canonical();
    let d1 = d0.swapped();
    let (m, h, f) = (8, 16, 32);
    let x = randt(&[m, h], 5);
    let w1 = randt(&[h, f], 6);
    let w2 = randt(&[f, h], 7);
    let y_ref = x.matmul(&w1).matmul(&w2);
    let x_shards = Layout3D::input(d0).scatter(&cube, &x);
    let w1_shards = Layout3D::weight(d0).scatter(&cube, &w1);
    let w2_shards = Layout3D::weight(d1).scatter(&cube, &w2);
    let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(p), rank);
        let h1 = threed::mm_nn(ep, &ctx, &x_shards[rank], &w1_shards[rank], d0);
        threed::mm_nn(ep, &ctx, &h1, &w2_shards[rank], d1)
    });
    // After two swaps the output is back in input-layout(d0) ≡ output(d1).
    let got = Layout3D::output(d1).gather(&cube, &out, m, h);
    assert!(got.max_abs_diff(&y_ref) < 1e-3);
}

#[test]
fn threed_full_linear_layer_with_bias_grads() {
    // Y = XW + b forward and full backward through Algorithms 1, 2, 7, 8.
    let p = 2;
    let cube = Cube::new(p);
    let d0 = Dirs::canonical();
    let d1 = d0.swapped();
    let (m, n, k) = (8, 16, 12);
    let x = randt(&[m, n], 8);
    let w = randt(&[n, k], 9);
    let bias = randt(&[k], 10);
    let dy = randt(&[m, k], 11);
    let y_ref = x.matmul(&w).add_row_vector(&bias);
    let dx_ref = dy.matmul_nt(&w);
    let dw_ref = x.matmul_tn(&dy);
    let db_ref = dy.sum_rows();

    let x_shards = Layout3D::input(d0).scatter(&cube, &x);
    let w_shards = Layout3D::weight(d0).scatter(&cube, &w);
    let b_shards = cubic::dist::DiagVec3D::for_dirs(d1).scatter(&cube, &bias);
    let dy_shards = Layout3D::output(d0).scatter(&cube, &dy);

    let out = run_spmd(8, NetModel::zero(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(p), rank);
        let mm = threed::mm_nn(ep, &ctx, &x_shards[rank], &w_shards[rank], d0);
        let y = threed::vec_op(ep, &ctx, &mm, b_shards[rank].as_ref(), d1, false);
        let (d_mm, db) = threed::add_vec_backward(ep, &ctx, &dy_shards[rank], d1);
        let (dx, dw) =
            threed::mm_nn_backward(ep, &ctx, &d_mm, &x_shards[rank], &w_shards[rank], d0);
        (y, dx, dw, db)
    });
    let y = Layout3D::output(d0)
        .gather(&cube, &out.iter().map(|o| o.0.clone()).collect::<Vec<_>>(), m, k);
    let dx = Layout3D::input(d0)
        .gather(&cube, &out.iter().map(|o| o.1.clone()).collect::<Vec<_>>(), m, n);
    let dw = Layout3D::weight(d0)
        .gather(&cube, &out.iter().map(|o| o.2.clone()).collect::<Vec<_>>(), n, k);
    let db = cubic::dist::DiagVec3D::for_dirs(d1)
        .gather(&cube, &out.iter().map(|o| o.3.clone()).collect::<Vec<_>>(), k);
    assert!(y.max_abs_diff(&y_ref) < 1e-3);
    assert!(dx.max_abs_diff(&dx_ref) < 1e-3);
    assert!(dw.max_abs_diff(&dw_ref) < 1e-3);
    assert!(db.max_abs_diff(&db_ref) < 1e-3);
}

#[test]
fn nt_and_tn_layout_shard_shapes_balance() {
    // The auxiliary layouts of Algorithms 3/5 also store 1/P per rank.
    let p = 2;
    for (rows, cols) in [(8usize, 16usize), (16, 8)] {
        let nt = Layout3D::nt_rhs(Dirs::canonical()).shard_shape(p, rows, cols);
        let tn = Layout3D::tn_lhs(Dirs::canonical()).shard_shape(p, rows, cols);
        assert_eq!(nt.0 * nt.1 * p * p * p, rows * cols);
        assert_eq!(tn.0 * tn.1 * p * p * p, rows * cols);
    }
}

#[test]
fn oned_vs_twod_vs_threed_same_linear() {
    // One linear layer computed under all three parallelisms from the same
    // global operands gives the same global result.
    let (m, n, k) = (8, 16, 8);
    let x = randt(&[m, n], 20);
    let w = randt(&[n, k], 21);
    let y_ref = x.matmul(&w);

    // 1-D column-parallel (no bias).
    let w_1d = Layout1D::ColShard.scatter(4, &w);
    let x1 = x.clone();
    let out1 = run_spmd(4, NetModel::zero(), move |rank, ep| {
        let ctx = oned::Ctx1D::new(4, rank);
        oned::col_linear_fwd(ep, &ctx, &x1, &w_1d[rank], None)
    });
    let y1 = Layout1D::ColShard.gather(&out1);
    assert!(y1.max_abs_diff(&y_ref) < 1e-3);

    // 2-D SUMMA.
    let mesh = Mesh::new(2);
    let x_2d = Layout2D::scatter(&mesh, &x);
    let w_2d = Layout2D::scatter(&mesh, &w);
    let out2 = run_spmd(4, NetModel::zero(), move |rank, ep| {
        let ctx = twod::Ctx2D::new(Mesh::new(2), rank);
        twod::summa_nn(ep, &ctx, &x_2d[rank], &w_2d[rank])
    });
    let y2 = Layout2D::gather(&mesh, &out2, m, k);
    assert!(y2.max_abs_diff(&y_ref) < 1e-3);

    // 3-D.
    let cube = Cube::new(2);
    let dirs = Dirs::canonical();
    let x_3d = Layout3D::input(dirs).scatter(&cube, &x);
    let w_3d = Layout3D::weight(dirs).scatter(&cube, &w);
    let out3 = run_spmd(8, NetModel::zero(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(2), rank);
        threed::mm_nn(ep, &ctx, &x_3d[rank], &w_3d[rank], dirs)
    });
    let y3 = Layout3D::output(dirs).gather(&cube, &out3, m, k);
    assert!(y3.max_abs_diff(&y_ref) < 1e-3);
}
