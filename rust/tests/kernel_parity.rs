//! Parity suite for the SIMD matmul microkernels.
//!
//! Every kernel variant the dispatcher can select must agree with a
//! same-accumulation-order oracle on every shape — including all the edge
//! geometries the packed-panel driver has to zero-pad:
//!
//! * **SIMD kernels (AVX2+FMA, NEON) vs the fused reference kernel**: both
//!   use round-once fused multiply-add in identical k-sequential chains, so
//!   for `k <= KC` (one k-block) results must match within 1 ulp — and in
//!   practice bit-for-bit (hardware FMA and `f32::mul_add` are both
//!   correctly rounded).
//! * **Scalar fallback vs a naive unfused triple loop**: same op sequence
//!   (`acc + a*b`, k-sequential), so the match must be within 1 ulp.
//!
//! Sweep: exhaustive `m, n, k ∈ 1..=17` (every microkernel-tile remainder
//! combination, 4913 shapes per form), the `64±1` boundary cube, and
//! 256-sized cases (the `KC` cache-block edge) — all three forms
//! (nn/nt/tn) each. Plus a multi-k-block case (`k > KC`) checked against an
//! f64 oracle, and the public `Tensor::matmul*` wrappers cross-checked so
//! the dispatch wiring itself is covered.

use cubic::rng::Xoshiro256;
use cubic::tensor::kernel::{self, gemm_strided, Kernel, KC};
use cubic::tensor::Tensor;

/// Ulp distance between two finite f32s (0 for exact equality, including
/// `0.0 == -0.0`).
fn ulp_diff(a: f32, b: f32) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return u64::MAX;
    }
    fn ordered(x: f32) -> i64 {
        let bits = x.to_bits();
        if bits & 0x8000_0000 != 0 {
            -((bits & 0x7FFF_FFFF) as i64)
        } else {
            bits as i64
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs()
}

fn fill(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// The three forms as (name, A-strides, B-strides) over row-major storage:
/// nn keeps both operands as stored; nt/tn swap one operand's strides.
#[derive(Clone, Copy)]
enum Form {
    Nn,
    Nt,
    Tn,
}

impl Form {
    fn name(self) -> &'static str {
        match self {
            Form::Nn => "nn",
            Form::Nt => "nt",
            Form::Tn => "tn",
        }
    }

    /// ((a_len, ars, aks), (b_len, brs, bcs)) for logical (m,k)·(k,n).
    #[allow(clippy::type_complexity)]
    fn strides(
        self,
        m: usize,
        n: usize,
        k: usize,
    ) -> ((usize, usize, usize), (usize, usize, usize)) {
        match self {
            // A stored (m,k), B stored (k,n).
            Form::Nn => ((m * k, k, 1), (k * n, n, 1)),
            // A stored (m,k), B stored (n,k) read as its transpose.
            Form::Nt => ((m * k, k, 1), (n * k, 1, k)),
            // A stored (k,m) read as its transpose, B stored (k,n).
            Form::Tn => ((k * m, 1, m), (k * n, n, 1)),
        }
    }
}

/// Same-order oracle: one k-sequential accumulation chain per element,
/// fused (`mul_add`) or unfused (`a*b + acc`).
#[allow(clippy::too_many_arguments)]
fn naive(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    ars: usize,
    aks: usize,
    b: &[f32],
    brs: usize,
    bcs: usize,
    fused: bool,
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                let (av, bv) = (a[i * ars + kk * aks], b[kk * brs + j * bcs]);
                acc = if fused { av.mul_add(bv, acc) } else { av * bv + acc };
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Run one (kernel, form, shape) case against its same-order oracle.
fn check(kern: Kernel, form: Form, m: usize, n: usize, k: usize, fused_oracle: bool) {
    let ((alen, ars, aks), (blen, brs, bcs)) = form.strides(m, n, k);
    let a = fill(1000 + (m * 31 + n * 7 + k) as u64, alen);
    let b = fill(2000 + (m + n * 13 + k * 5) as u64, blen);
    let mut c = vec![0.0f32; m * n];
    gemm_strided(kern, m, n, k, &a, ars, aks, &b, brs, bcs, &mut c);
    let want = naive(m, n, k, &a, ars, aks, &b, brs, bcs, fused_oracle);
    for (idx, (&got, &w)) in c.iter().zip(&want).enumerate() {
        let d = ulp_diff(got, w);
        assert!(
            d <= 1,
            "{} {} ({m},{n},{k}) elem {idx}: got {got:e} want {w:e} ({d} ulp)",
            kern.name,
            form.name()
        );
    }
}

/// Kernels to sweep, paired with the oracle rounding they must match:
/// scalar ↔ unfused, every detected SIMD variant (and the reference
/// kernel itself, as a self-check) ↔ fused.
fn kernels_under_test() -> Vec<(Kernel, bool)> {
    let mut v: Vec<(Kernel, bool)> = Vec::new();
    for k in kernel::available() {
        v.push((*k, k.name != "scalar"));
    }
    v.push((kernel::reference_kernel(), true));
    v
}

#[test]
fn exhaustive_small_dims_all_forms() {
    let kernels = kernels_under_test();
    for &(kern, fused) in &kernels {
        for form in [Form::Nn, Form::Nt, Form::Tn] {
            for m in 1..=17 {
                for n in 1..=17 {
                    for k in 1..=17 {
                        check(kern, form, m, n, k, fused);
                    }
                }
            }
        }
    }
}

#[test]
fn cache_block_boundary_dims_all_forms() {
    let kernels = kernels_under_test();
    let boundary = [63usize, 64, 65];
    for &(kern, fused) in &kernels {
        for form in [Form::Nn, Form::Nt, Form::Tn] {
            for &m in &boundary {
                for &n in &boundary {
                    for &k in &boundary {
                        check(kern, form, m, n, k, fused);
                    }
                }
            }
            // KC-edge cases: 256 in each position (k = 256 is exactly one
            // full k-block — the largest single-chain depth).
            for &(m, n, k) in &[(256, 9, 17), (9, 256, 17), (9, 17, 256), (256, 64, 8)] {
                check(kern, form, m, n, k, fused);
            }
        }
    }
    // Full 256³ once, nn only (the microbench headline shape).
    for &(kern, fused) in &kernels {
        check(kern, Form::Nn, 256, 256, 256, fused);
    }
}

#[test]
fn multi_kblock_and_cache_edges_match_f64_oracle() {
    // k > KC splits the accumulation across k-blocks (C += per block), so
    // same-order ulp comparison no longer applies; check against an f64
    // oracle instead. Shape straddles MC (128) and NC (256) too.
    let (m, n, k) = (129, 257, KC + 41);
    let a = fill(7, m * k);
    let b = fill(8, k * n);
    for kern in kernel::available() {
        let mut c = vec![0.0f32; m * n];
        gemm_strided(*kern, m, n, k, &a, k, 1, &b, n, 1, &mut c);
        for i in (0..m).step_by(17) {
            for j in (0..n).step_by(19) {
                let want: f64 = (0..k).map(|kk| a[i * k + kk] as f64 * b[kk * n + j] as f64).sum();
                let got = c[i * n + j] as f64;
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "{}: ({i},{j}) got {got} want {want}",
                    kern.name
                );
            }
        }
    }
}

#[test]
fn tensor_wrappers_dispatch_to_the_same_kernels() {
    // The public matmul API must produce exactly what the selected kernel
    // produces through the raw driver — pins the matmul.rs wiring.
    let (m, n, k) = (13, 11, 9);
    let kern = kernel::selected();
    let a = fill(21, m * k);
    let b = fill(22, k * n);
    let ta = Tensor::from_vec(&[m, k], a.clone());
    let tb = Tensor::from_vec(&[k, n], b.clone());
    let mut c = vec![0.0f32; m * n];
    gemm_strided(kern, m, n, k, &a, k, 1, &b, n, 1, &mut c);
    assert_eq!(ta.matmul(&tb).data(), &c[..], "matmul_nn wiring");
    let tbt = tb.transpose();
    let mut c_nt = vec![0.0f32; m * n];
    gemm_strided(kern, m, n, k, &a, k, 1, tbt.data(), 1, k, &mut c_nt);
    assert_eq!(ta.matmul_nt(&tbt).data(), &c_nt[..], "matmul_nt wiring");
    let tat = ta.transpose();
    let mut c_tn = vec![0.0f32; m * n];
    gemm_strided(kern, m, n, k, tat.data(), 1, m, &b, n, 1, &mut c_tn);
    assert_eq!(tat.matmul_tn(&tb).data(), &c_tn[..], "matmul_tn wiring");
}
