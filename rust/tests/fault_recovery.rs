//! Fault-injection + recovery matrix: seeded drops, straggler links and
//! rank crashes across every mesh kind, under both overlap settings.
//!
//! The headline guarantee pinned here: a run that crashes at step S and
//! recovers — from a checkpoint, a hybrid replica donation, or a fresh
//! restart — produces a loss curve **bit-identical** to the fault-free
//! run, and with faults disabled the supervised engine is bit-identical
//! (virtual clock included) to the plain engine.

use cubic::comm::NetModel;
use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
use cubic::engine::{run_training, run_training_supervised, run_training_with_checkpoint};
use cubic::topology::{HybridInner, Parallelism, PipelineInner};
use std::path::{Path, PathBuf};

/// Every mesh kind at its smallest non-trivial extent (tiny model fits all).
fn all_kinds() -> Vec<(Parallelism, usize)> {
    vec![
        (Parallelism::Seq, 1),
        (Parallelism::OneD, 4),
        (Parallelism::TwoD, 2),
        (Parallelism::ThreeD, 2),
        (Parallelism::TwoFiveD { depth: 2 }, 2),
        (Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 2),
        (Parallelism::Pipeline { stages: 2, micro_batches: 4, inner: PipelineInner::OneD }, 2),
    ]
}

fn base_cfg(par: Parallelism, edge: usize) -> CubicConfig {
    // Pipeline points need the layer stack to divide across their stages;
    // every other kind keeps the single-layer tiny model.
    let layers = match par {
        Parallelism::Pipeline { stages, .. } => stages,
        _ => 1,
    };
    CubicConfig {
        model: ModelConfig { layers, ..ModelConfig::tiny() },
        train: TrainConfig { steps: 6, lr: 3e-3, warmup: 2, ckpt_every: 2, ..Default::default() },
        parallelism: par,
        edge,
        ..CubicConfig::default()
    }
}

fn net(overlap: bool) -> NetModel {
    let mut n = NetModel::longhorn_v100();
    n.set_overlap(overlap);
    n
}

fn tmp_dir(label: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cubic-faultrec-{}-{label}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn read_rank_files(dir: &Path, world: usize) -> Vec<Vec<u8>> {
    (0..world)
        .map(|r| {
            let p = dir.join(format!("rank-{r}.bin"));
            std::fs::read(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
        })
        .collect()
}

/// The matrix: every kind × both overlap settings. Three runs each —
/// plain engine (reference), supervised fault-free (must be bit-identical,
/// clock included), supervised with a rank crashed at step 3 (must recover
/// and land on the same losses and the same final checkpoint bytes).
#[test]
fn crash_recovery_is_bit_identical_across_all_kinds() {
    for (par, edge) in all_kinds() {
        let world = par.world_size(edge);
        for overlap in [false, true] {
            let label = format!("{}-ov{}", par.name(), overlap as u8);
            let cfg = base_cfg(par, edge);
            let clean = run_training(&cfg, net(overlap)).unwrap();
            assert_eq!(clean.losses.len(), 6);

            // Fault-free supervised path: same numerics, same clock.
            let dir_clean = tmp_dir(&format!("clean-{label}"));
            let sup = run_training_with_checkpoint(&cfg, net(overlap), &dir_clean).unwrap();
            assert_eq!(sup.losses, clean.losses, "{label}: supervised fault-free diverged");
            assert_eq!(
                sup.metrics.virtual_time, clean.metrics.virtual_time,
                "{label}: supervision must not perturb the virtual clock"
            );
            assert_eq!(sup.recoveries, 0, "{label}");

            // Crash a rank entering step 3 (checkpoint boundary is step 2).
            let mut faulty_cfg = cfg.clone();
            faulty_cfg.faults.seed = 9;
            faulty_cfg.faults.crash = Some((world - 1, 3));
            assert!(faulty_cfg.faults.is_active());
            let dir_faulty = tmp_dir(&format!("crash-{label}"));
            let rec = run_training_with_checkpoint(&faulty_cfg, net(overlap), &dir_faulty)
                .unwrap_or_else(|e| panic!("{label}: recovery failed: {e}"));
            assert_eq!(rec.losses, clean.losses, "{label}: recovered run diverged");
            assert_eq!(rec.recoveries, 1, "{label}");
            assert!(
                rec.metrics.virtual_time > clean.metrics.virtual_time,
                "{label}: recovery replay must cost virtual time"
            );

            // Crash-consistent persistence: the final checkpoints of the
            // recovered and the fault-free runs are byte-identical.
            assert_eq!(
                read_rank_files(&dir_faulty, world),
                read_rank_files(&dir_clean, world),
                "{label}: final checkpoint bytes differ after recovery"
            );
            let _ = std::fs::remove_dir_all(&dir_clean);
            let _ = std::fs::remove_dir_all(&dir_faulty);
        }
    }
}

/// Hybrid meshes recover a crashed rank from the surviving replica over
/// comm — no checkpoint directory involved at all.
#[test]
fn hybrid_replica_donation_recovers_without_checkpoints() {
    let par = Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD };
    let cfg = base_cfg(par, 2);
    let clean = run_training(&cfg, net(true)).unwrap();
    let mut faulty = cfg.clone();
    faulty.faults.seed = 5;
    // Rank 1 (replica 0, inner rank 1) dies; rank 5 is its counterpart.
    faulty.faults.crash = Some((1, 3));
    let rec = run_training_supervised(&faulty, net(true), None).unwrap();
    assert_eq!(rec.losses, clean.losses, "donated state must replay bit-identically");
    assert_eq!(rec.recoveries, 1);
}

/// Under ZeRO the surviving replica does NOT hold the dead rank's Adam
/// moment partition, so replica donation is off the table: the engine
/// must fall back to the checkpoint Restore path — and still land on a
/// loss curve bit-identical to the fault-free ZeRO run (which is itself
/// bit-identical to ZeRO-off, pinned in `model_parity`).
#[test]
fn zero_crash_recovers_from_checkpoint_not_donation() {
    let par = Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD };
    let mut cfg = base_cfg(par, 2);
    cfg.zero_stage = 1;
    let world = par.world_size(2);
    let clean = run_training(&cfg, net(true)).unwrap();

    let mut faulty = cfg.clone();
    faulty.faults.seed = 5;
    // Same crash point as the donation test: rank 1 entering step 3, one
    // step past the step-2 checkpoint boundary.
    faulty.faults.crash = Some((1, 3));
    let dir = tmp_dir("zero-crash");
    let rec = run_training_with_checkpoint(&faulty, net(true), &dir).unwrap();
    assert_eq!(rec.losses, clean.losses, "ZeRO restore must replay bit-identically");
    assert_eq!(rec.recoveries, 1);
    assert!(
        rec.metrics.virtual_time > clean.metrics.virtual_time,
        "checkpoint replay must cost virtual time (donation would too, but \
         this pins that SOME recovery work happened)"
    );
    // The checkpoint dir holds a file per rank — restore was possible.
    assert_eq!(read_rank_files(&dir, world).len(), world);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Without a checkpoint dir or a replica, a crash falls back to a fresh
/// restart from step 0 — and still converges to the identical curve.
#[test]
fn crash_without_checkpoint_restarts_fresh() {
    let cfg = base_cfg(Parallelism::TwoD, 2);
    let clean = run_training(&cfg, net(true)).unwrap();
    let mut faulty = cfg.clone();
    faulty.faults.crash = Some((1, 1));
    let rec = run_training_supervised(&faulty, net(true), None).unwrap();
    assert_eq!(rec.losses, clean.losses);
    assert_eq!(rec.recoveries, 1);
    // Replayed from scratch: about double the clean virtual time.
    assert!(rec.metrics.virtual_time > 1.5 * clean.metrics.virtual_time);
}

/// Message drops and straggler links perturb only the virtual clock —
/// numerics stay bit-identical, and the injected retries are visible in
/// the run metrics deterministically.
#[test]
fn drops_and_delays_leave_numerics_bit_identical() {
    let cfg = base_cfg(Parallelism::ThreeD, 2);
    let clean = run_training(&cfg, net(true)).unwrap();
    let mut faulty = cfg.clone();
    faulty.faults.seed = 7;
    faulty.faults.drop_p = 0.05;
    faulty.faults.delay = Some((Some(0), None, 2e-3)); // rank 0 straggles
    let a = run_training_supervised(&faulty, net(true), None).unwrap();
    assert_eq!(a.losses, clean.losses, "drops/delays must never change numerics");
    assert!(a.metrics.retries > 0, "drop_p 0.05 over a full run must drop something");
    assert!(
        a.metrics.virtual_time > clean.metrics.virtual_time,
        "retry stalls and the straggler link must show up on the clock"
    );
    // Seeded injection is fully deterministic: same plan, same counters.
    let b = run_training_supervised(&faulty, net(true), None).unwrap();
    assert_eq!(a.metrics.retries, b.metrics.retries);
    assert_eq!(a.metrics.timeouts, b.metrics.timeouts);
    assert_eq!(a.metrics.virtual_time, b.metrics.virtual_time);
    assert_eq!(a.recoveries, b.recoveries);
}

/// The recovery budget is a clean typed error, not a hang: a crash with
/// `max_recoveries = 0` surfaces the per-rank failure in the message.
#[test]
fn recovery_budget_exhaustion_is_a_clean_error() {
    let mut cfg = base_cfg(Parallelism::TwoD, 2);
    cfg.faults.crash = Some((0, 1));
    cfg.faults.max_recoveries = 0;
    let err = run_training_supervised(&cfg, net(true), None).unwrap_err().to_string();
    assert!(err.contains("training failed after 0 recoveries"), "{err}");
    assert!(err.contains("rank 0"), "{err}");
    assert!(err.contains("crash"), "{err}");
}
