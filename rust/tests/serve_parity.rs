//! Serving correctness: token-by-token KV-cached decode computes the SAME
//! function as the full-sequence training forward — bitwise, per slot, per
//! position — on every mesh kind and under both overlap schedules.
//!
//! Why bitwise is possible at all: prefill IS `block_fwd` (the training
//! forward) with the backward stash dropped; the causal mask's `-1e9`
//! makes future positions exact additive identities in the softmax (their
//! probabilities underflow to +0.0), so a row's output depends only on
//! rows ≤ it; and `ModelConfig::validate_serve`'s slot-divisibility rules
//! make every ring reduction chunk land on whole slot windows in BOTH the
//! padded prefill grid and the one-row-per-slot decode grid, so each
//! output element is folded in the identical order in the two runs.

use cubic::comm::NetModel;
use cubic::config::{ModelConfig, ServeConfig};
use cubic::model::{init_dense_blocks, BlockTensors};
use cubic::parallel::{ops_for, pipeline::Pipeline, ParallelOps};
use cubic::rng::Xoshiro256;
use cubic::serve::build_kv;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{HybridInner, Parallelism, PipelineInner};

/// Every parallelism point the crate implements, with its test edge
/// (mirrors `model_parity::ALL_ENVS`).
const ALL_ENVS: [(Parallelism, usize); 7] = [
    (Parallelism::Seq, 1),
    (Parallelism::OneD, 4),
    (Parallelism::TwoD, 2),
    (Parallelism::ThreeD, 2),
    (Parallelism::TwoFiveD { depth: 2 }, 2),
    (Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2),
    (
        Parallelism::Pipeline { stages: 2, micro_batches: 4, inner: PipelineInner::OneD },
        2,
    ),
];

fn tiny() -> ModelConfig {
    ModelConfig { layers: 2, ..ModelConfig::tiny() }
}

fn randt(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Tensor::randn(shape, 0.5, &mut rng)
}

/// This rank's ops + real sharded layer slice (the serve engine's private
/// `build_rank`, re-derived through public API for the test).
fn build_rank(
    par: Parallelism,
    edge: usize,
    rank: usize,
    cfg: &ModelConfig,
    seed: u64,
) -> (Box<dyn ParallelOps>, Vec<BlockTensors>) {
    let (ops, range): (Box<dyn ParallelOps>, std::ops::Range<usize>) = match par {
        Parallelism::Pipeline { stages, micro_batches, inner } => {
            let p = Pipeline::for_kind(stages, micro_batches, inner, edge, rank);
            let r = p.layer_range(cfg.layers);
            (Box::new(p), r)
        }
        _ => (ops_for(par, edge, rank), 0..cfg.layers),
    };
    let dense = init_dense_blocks(cfg, seed);
    let blocks: Vec<BlockTensors> = dense[range].iter().map(|b| ops.shard_block(b)).collect();
    (ops, blocks)
}

/// One parallelism point, one overlap schedule: run the full-sequence
/// forward at `T = P + G` positions per slot, then prefill on the first
/// `P` positions and teacher-force `G` decode steps over the remaining
/// given input rows. Every prefill row and every decode row must equal
/// the full forward's row at the same (slot, position) bitwise.
fn check_decode_parity(par: Parallelism, edge: usize, overlap: bool) {
    let cfg = tiny();
    let slots = cfg.batch; // 4
    let (pp, gg) = (8usize, 8usize);
    let tt = pp + gg;
    // The test points must actually satisfy the serve shape rules — the
    // divisibility table is what makes the bitwise claim below true.
    cfg.validate_serve(
        par,
        edge,
        &ServeConfig {
            slots,
            max_seq: tt,
            prompt_len: pp,
            gen_len: gg,
            requests: 1,
            arrival_rate: 1.0,
            seed: 1,
        },
    )
    .unwrap_or_else(|e| panic!("{par:?}: {e}"));
    let hidden = cfg.hidden;
    // Global input: slot s owns rows [s·T, (s+1)·T).
    let x = randt(&[slots * tt, hidden], 31);
    let world = par.world_size(edge);
    let mut net = NetModel::zero();
    net.overlap = overlap;
    let (cfg2, x2) = (cfg.clone(), x.clone());
    let out = run_spmd(world, net, move |rank, ep| {
        let (ops, blocks) = build_rank(par, edge, rank, &cfg2, 42);
        let ops = ops.as_ref();
        // Run A — the reference: one full-length prefill (== the training
        // forward at seq T); its KV cache is filled but unused.
        let cfg_full = ModelConfig { seq: tt, batch: slots, ..cfg2.clone() };
        let mut kv_full = build_kv(ops, blocks.len(), &cfg2, slots, tt, false);
        let slots_loc = kv_full[0].slots;
        let xa = ops.scatter_activation(ep, &x2);
        let y_full =
            ops.serve_prefill(ep, &blocks, &xa, &cfg_full, &vec![tt; slots_loc], &mut kv_full);
        // Run B — serving: prefill the first P positions of each slot…
        let pre_parts: Vec<Tensor> =
            (0..slots).map(|s| x2.block(s * tt, 0, pp, hidden)).collect();
        let x_pre = Tensor::concat_rows(&pre_parts);
        let cfg_pre = ModelConfig { seq: pp, batch: slots, ..cfg2.clone() };
        let mut kv = build_kv(ops, blocks.len(), &cfg2, slots, tt, false);
        let xb = ops.scatter_activation(ep, &x_pre);
        let y_pre =
            ops.serve_prefill(ep, &blocks, &xb, &cfg_pre, &vec![pp; slots_loc], &mut kv);
        // …then decode the remaining G positions one token at a time,
        // teacher-forced from the same global input rows the full forward
        // saw.
        let mut decode_outs = Vec::with_capacity(gg);
        for g in 0..gg {
            let pos = pp + g;
            let step_parts: Vec<Tensor> =
                (0..slots).map(|s| x2.block(s * tt + pos, 0, 1, hidden)).collect();
            let x_step = Tensor::concat_rows(&step_parts);
            let xg = ops.scatter_activation(ep, &x_step);
            decode_outs.push(ops.serve_decode(ep, &blocks, &xg, &cfg2, &mut kv));
        }
        (y_full, y_pre, decode_outs, slots_loc)
    });
    assert_eq!(out.len(), world);
    for (rank, (y_full, y_pre, douts, slots_loc)) in out.iter().enumerate() {
        let (_, cols) = y_full.dims2();
        assert_eq!(douts.len(), gg);
        for s in 0..*slots_loc {
            for p in 0..pp {
                assert_eq!(
                    y_pre.block(s * pp + p, 0, 1, cols).data(),
                    y_full.block(s * tt + p, 0, 1, cols).data(),
                    "{par:?} overlap={overlap} rank {rank} slot {s} prefill pos {p}"
                );
            }
            for (g, yd) in douts.iter().enumerate() {
                let pos = pp + g;
                assert_eq!(
                    yd.block(s, 0, 1, cols).data(),
                    y_full.block(s * tt + pos, 0, 1, cols).data(),
                    "{par:?} overlap={overlap} rank {rank} slot {s} decode pos {pos}"
                );
            }
        }
    }
}

#[test]
fn decode_matches_full_forward_every_kind_both_overlap() {
    for (par, edge) in ALL_ENVS {
        for overlap in [false, true] {
            check_decode_parity(par, edge, overlap);
        }
    }
}

#[test]
fn ragged_prompts_decode_matches_full_forward() {
    // Continuous batching admits ragged prompt lengths into one padded
    // prefill window: slot s holds `lens[s] ≤ P` real rows (the rest of
    // its window is junk the causal mask keeps out of every used row).
    // After harvest, one decode step at each slot's own depth must equal
    // the full forward's row at position lens[s] — per-slot KV depths
    // diverge, which the all-slots decode step has to handle.
    let cfg = tiny();
    let slots = cfg.batch; // 4
    let pp = 8usize;
    let win = pp + 1; // teacher-forced next token lives at index lens[s]
    let lens = [3usize, 8, 1, 5];
    let hidden = cfg.hidden;
    let x = randt(&[slots * win, hidden], 33);
    for (par, edge) in [(Parallelism::Seq, 1), (Parallelism::OneD, 4)] {
        let world = par.world_size(edge);
        let (cfg2, x2) = (cfg.clone(), x.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let (ops, blocks) = build_rank(par, edge, rank, &cfg2, 42);
            let ops = ops.as_ref();
            // Reference: full forward over every slot's whole window.
            let cfg_full = ModelConfig { seq: win, batch: slots, ..cfg2.clone() };
            let mut kv_full = build_kv(ops, blocks.len(), &cfg2, slots, win, false);
            let slots_loc = kv_full[0].slots;
            let xa = ops.scatter_activation(ep, &x2);
            let y_full =
                ops.serve_prefill(ep, &blocks, &xa, &cfg_full, &vec![win; slots_loc], &mut kv_full);
            // Serving: padded prefill with ragged lens, one decode step.
            let pre_parts: Vec<Tensor> =
                (0..slots).map(|s| x2.block(s * win, 0, pp, hidden)).collect();
            let x_pre = Tensor::concat_rows(&pre_parts);
            let cfg_pre = ModelConfig { seq: pp, batch: slots, ..cfg2.clone() };
            let mut kv = build_kv(ops, blocks.len(), &cfg2, slots, win, false);
            let xb = ops.scatter_activation(ep, &x_pre);
            let _ = ops.serve_prefill(ep, &blocks, &xb, &cfg_pre, &lens.to_vec(), &mut kv);
            let step_parts: Vec<Tensor> = (0..slots)
                .map(|s| x2.block(s * win + lens[s], 0, 1, hidden))
                .collect();
            let x_step = Tensor::concat_rows(&step_parts);
            let xg = ops.scatter_activation(ep, &x_step);
            let yd = ops.serve_decode(ep, &blocks, &xg, &cfg2, &mut kv);
            (y_full, yd)
        });
        for (rank, (y_full, yd)) in out.iter().enumerate() {
            let (_, cols) = y_full.dims2();
            for s in 0..slots {
                assert_eq!(
                    yd.block(s, 0, 1, cols).data(),
                    y_full.block(s * win + lens[s], 0, 1, cols).data(),
                    "{par:?} rank {rank} slot {s} (prompt len {})",
                    lens[s]
                );
            }
        }
    }
}

#[test]
fn decode_steady_state_no_alloc_growth() {
    // Satellite: inference holds only KV — no backward stashes — and the
    // decode loop's collective/boundary buffers recycle through the pool.
    // After a one-step warmup, further decode steps must take every pooled
    // buffer as a hit (0 misses ⇒ 0 steady-state allocation growth), the
    // same counter pin the training boundary paths use.
    let cfg = tiny();
    let slots = cfg.batch;
    let pp = 4usize;
    let steps = 6usize;
    let hidden = cfg.hidden;
    let x = randt(&[slots * pp, hidden], 35);
    let xd0 = randt(&[slots, hidden], 36);
    let out = run_spmd(4, NetModel::zero(), move |rank, ep| {
        let (ops, blocks) = build_rank(Parallelism::OneD, 4, rank, &cfg, 42);
        let ops = ops.as_ref();
        let max_seq = pp + steps + 2;
        let mut kv = build_kv(ops, blocks.len(), &cfg, slots, max_seq, false);
        let slots_loc = kv[0].slots;
        let cfg_pre = ModelConfig { seq: pp, batch: slots, ..cfg.clone() };
        let xb = ops.scatter_activation(ep, &x);
        let _ = ops.serve_prefill(ep, &blocks, &xb, &cfg_pre, &vec![pp; slots_loc], &mut kv);
        // Warmup decode step allocates the loop's buffers once…
        let mut xd = ops.serve_decode(ep, &blocks, &xd0, &cfg, &mut kv);
        let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
        // …then the steady state must recycle (per-endpoint counters; the
        // global metrics would race with parallel tests).
        for _ in 0..steps {
            xd = ops.serve_decode(ep, &blocks, &xd, &cfg, &mut kv);
        }
        (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
    });
    for (rank, (_hits, misses)) in out.iter().enumerate() {
        assert_eq!(
            *misses, 0,
            "rank {rank}: decode loop must not allocate pooled buffers after warmup"
        );
    }
}
