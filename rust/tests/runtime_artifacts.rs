//! Integration: the PJRT runtime executes the AOT artifacts and matches the
//! native Rust kernels — the L1/L2/L3 composition proof.
//!
//! Requires `make artifacts` (skips gracefully if the bundle is missing so
//! `cargo test` stays green in a fresh checkout).

use cubic::model::{self, ParEnv};
use cubic::rng::Xoshiro256;
use cubic::runtime::Runtime;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

fn randt(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Tensor::randn(shape, 0.5, &mut rng)
}

#[test]
fn pjrt_matmul_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    // Use any mm_nn entry from the manifest.
    let name = rt
        .manifest
        .names()
        .into_iter()
        .find(|n| n.starts_with("mm_nn_"))
        .expect("bundle has mm_nn entries");
    let entry = rt.manifest.get(&name).unwrap().clone();
    let a = randt(&entry.in_shapes[0], 1);
    let b = randt(&entry.in_shapes[1], 2);
    let got = rt.handle().execute(&name, &[a.clone(), b.clone()]).unwrap();
    let want = a.matmul(&b);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.max_abs_diff(&want) < 1e-3,
        "{name}: PJRT vs native diff {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn pjrt_all_three_matmul_forms_match_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    for form in ["nn", "nt", "tn"] {
        let Some(name) = rt
            .manifest
            .names()
            .into_iter()
            .find(|n| n.starts_with(&format!("mm_{form}_")))
        else {
            continue;
        };
        let e = rt.manifest.get(&name).unwrap().clone();
        let a = randt(&e.in_shapes[0], 3);
        let b = randt(&e.in_shapes[1], 4);
        let got = rt.handle().execute(&name, &[a.clone(), b.clone()]).unwrap();
        let want = match form {
            "nn" => a.matmul(&b),
            "nt" => a.matmul_nt(&b),
            _ => a.matmul_tn(&b),
        };
        assert!(got.max_abs_diff(&want) < 1e-3, "{name}");
    }
}

#[test]
fn pjrt_handle_works_from_worker_threads() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let name = rt
        .manifest
        .names()
        .into_iter()
        .find(|n| n.starts_with("mm_nn_"))
        .unwrap();
    let e = rt.manifest.get(&name).unwrap().clone();
    let h = rt.handle();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let h = h.clone();
        let name = name.clone();
        let e = e.clone();
        joins.push(std::thread::spawn(move || {
            let a = randt(&e.in_shapes[0], 10 + t);
            let b = randt(&e.in_shapes[1], 20 + t);
            let got = h.execute(&name, &[a.clone(), b.clone()]).unwrap();
            got.max_abs_diff(&a.matmul(&b))
        }));
    }
    for j in joins {
        assert!(j.join().unwrap() < 1e-3);
    }
}

#[test]
fn pjrt_fused_block_matches_rust_seq_model() {
    // The L2 `block_seq` artifact (a whole fused transformer block authored
    // in JAX + Pallas) must agree with the independent Rust Seq model on
    // the same parameters — the strongest cross-language parity check.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let Some(name) = rt
        .manifest
        .names()
        .into_iter()
        .find(|n| n.starts_with("block_seq_"))
    else {
        eprintln!("skipping: no block_seq artifact");
        return;
    };
    // tiny config (kept in sync with aot.py CONFIGS["tiny"]).
    let cfg = cubic::config::ModelConfig::tiny();
    let rows = cfg.batch * cfg.seq;
    let x = randt(&[rows, cfg.hidden], 30);

    // One dense block; note the JAX model consumes [Wq|Wk|Wv] per head
    // exactly like ours (head-major triples? see python/compile/model.py:
    // it splits qkv into thirds → [Q|K|V] global). Convert our head-major
    // w_qkv/b_qkv into the python layout before feeding the artifact.
    let dense = model::init_dense_blocks(&cfg, 99).remove(0);
    let hd = cfg.hidden / cfg.heads;
    let to_python_qkv = |w: &Tensor| -> Tensor {
        // columns: ours g-major [q_g|k_g|v_g]; python wants [Q|K|V].
        let (r, _c) = w.dims2();
        let mut out = Tensor::zeros(&[r, 3 * cfg.hidden]);
        for g in 0..cfg.heads {
            for (part, dst_base) in [(0, 0), (1, cfg.hidden), (2, 2 * cfg.hidden)] {
                let src = w.block(0, g * 3 * hd + part * hd, r, hd);
                out.set_block(0, dst_base + g * hd, &src);
            }
        }
        out
    };
    let w_qkv_py = to_python_qkv(&dense.w_qkv);
    let b_qkv_py = to_python_qkv(&dense.b_qkv.reshape(&[1, 3 * cfg.hidden]))
        .into_reshape(&[3 * cfg.hidden]);

    let inputs = vec![
        x.clone(),
        dense.ln1_g.clone(),
        dense.ln1_b.clone(),
        w_qkv_py,
        b_qkv_py,
        dense.w_proj.clone(),
        dense.b_proj.clone(),
        dense.ln2_g.clone(),
        dense.ln2_b.clone(),
        dense.w_fc1.clone(),
        dense.b_fc1.clone(),
        dense.w_fc2.clone(),
        dense.b_fc2.clone(),
    ];
    let got = rt.handle().execute(&name, &inputs).unwrap();

    // Rust Seq reference. NOTE python attention concatenates head outputs
    // in head order and w_proj rows are head-ordered the same way, so no
    // permutation is needed on the output side.
    let p = dense.shard(&cubic::dist::ShardSpec::seq());
    let cfg2 = cfg.clone();
    let want = run_spmd(1, cubic::comm::NetModel::zero(), move |_, ep| {
        let env = ParEnv::seq();
        model::core_fwd(ep, env.ops(), &[p.clone()], &x, &cfg2).0
    })
    .pop()
    .unwrap();
    assert_eq!(got.shape(), want.shape());
    let diff = got.rel_l2_error(&want);
    assert!(diff < 1e-3, "block_seq rel error {diff}");
}
