//! Property-based tests over randomized shapes, cube sizes, direction
//! triples and seeds. No `proptest` in the offline crate set, so this file
//! carries its own tiny harness: seeded generators + a fixed case budget
//! per property, with the failing case's parameters printed on assert.
//!
//! Invariants pinned here:
//! * shard layouts tile the global matrix exactly (no gaps/overlaps);
//! * scatter ∘ gather = identity for every layout;
//! * collective byte ledgers match the closed-form cost model for random
//!   shapes/groups;
//! * distributed mm == dense for random shapes/dirs;
//! * virtual clocks are monotone and group-synchronized after collectives.

use cubic::collectives::{all_gather, all_reduce, reduce_scatter};
use cubic::comm::NetModel;
use cubic::costmodel;
use cubic::dist::{DiagVec3D, Dirs, Layout3D};
use cubic::parallel::threed::{mm_nn, Ctx3D};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{Axis, Cube};

struct Gen(Xoshiro256);

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen(Xoshiro256::seed_from_u64(seed))
    }

    fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.0.next_below((hi - lo + 1) as u64) as usize
    }

    fn dirs(&mut self) -> Dirs {
        let mut axes = [Axis::X, Axis::Y, Axis::Z];
        // Fisher-Yates.
        for i in (1..3).rev() {
            let j = self.0.next_below((i + 1) as u64) as usize;
            axes.swap(i, j);
        }
        Dirs { a: axes[0], b: axes[1], c: axes[2] }
    }

    fn tensor(&mut self, shape: &[usize]) -> Tensor {
        Tensor::randn(shape, 1.0, &mut self.0)
    }
}

#[test]
fn prop_layout3d_tiles_exactly() {
    // Every cell of the global matrix is covered by exactly one shard.
    for case in 0..40u64 {
        let mut g = Gen::new(1000 + case);
        let p = g.usize_in(1, 3);
        let cube = Cube::new(p);
        let rows = p * p * g.usize_in(1, 4);
        let cols = p * p * g.usize_in(1, 4);
        let dirs = g.dirs();
        for layout in [
            Layout3D::input(dirs),
            Layout3D::weight(dirs),
            Layout3D::output(dirs),
        ] {
            let mut cover = vec![0u8; rows * cols];
            for r in 0..cube.size() {
                let (r0, c0, sr, sc) = layout.shard_bounds(&cube, cube.coord_of(r), rows, cols);
                for i in r0..r0 + sr {
                    for j in c0..c0 + sc {
                        cover[i * cols + j] += 1;
                    }
                }
            }
            assert!(
                cover.iter().all(|&c| c == 1),
                "case {case}: p={p} {rows}x{cols} dirs {dirs:?} layout {layout:?} not a partition"
            );
        }
    }
}

#[test]
fn prop_scatter_gather_identity() {
    for case in 0..30u64 {
        let mut g = Gen::new(2000 + case);
        let p = g.usize_in(1, 3);
        let cube = Cube::new(p);
        let rows = p * p * g.usize_in(1, 3);
        let cols = p * p * g.usize_in(1, 3);
        let dirs = g.dirs();
        let t = g.tensor(&[rows, cols]);
        for layout in [Layout3D::input(dirs), Layout3D::weight(dirs)] {
            let shards = layout.scatter(&cube, &t);
            let back = layout.gather(&cube, &shards, rows, cols);
            assert_eq!(back, t, "case {case}: p={p} dirs {dirs:?}");
        }
        // Diagonal vectors too.
        let v = g.tensor(&[cols]);
        let spec = DiagVec3D::for_dirs(dirs);
        let shards = spec.scatter(&cube, &v);
        assert_eq!(spec.gather(&cube, &shards, cols), v, "case {case} vec");
    }
}

#[test]
fn prop_collective_ledger_matches_cost_model() {
    for case in 0..15u64 {
        let mut g = Gen::new(3000 + case);
        let world = g.usize_in(2, 8);
        let elems = g.usize_in(1, 500);
        let bytes = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..world).collect();
            let t = Tensor::full(&[elems], rank as f32);
            let _ = all_reduce(ep, &group, &t);
            ep.stats.bytes_sent
        });
        let want = costmodel::ring_all_reduce_bytes(world as u64, elems as u64);
        for (rank, &b) in bytes.iter().enumerate() {
            assert_eq!(b, want, "case {case}: world={world} elems={elems} rank={rank}");
        }
    }
}

#[test]
fn prop_all_gather_then_reduce_scatter_roundtrip() {
    // reduce_scatter(all_gather(x) scaled) recovers a scaled shard: checks
    // the two rings compose coherently for random sizes.
    for case in 0..15u64 {
        let mut g = Gen::new(4000 + case);
        let world = g.usize_in(2, 6);
        let elems = g.usize_in(1, 64);
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..world).collect();
            let mine = Tensor::full(&[elems], (rank + 1) as f32);
            let parts = all_gather(ep, &group, &mine);
            // Feed everyone's parts back as reduce-scatter contributions:
            // destination k receives sum over ranks of part[k] = world·(k+1).
            let got = reduce_scatter(ep, &group, parts);
            got.data().to_vec()
        });
        for (rank, v) in out.iter().enumerate() {
            let want = (world * (rank + 1)) as f32;
            assert!(
                v.iter().all(|&x| x == want),
                "case {case}: world={world} rank={rank}: {v:?} != {want}"
            );
        }
    }
}

#[test]
fn prop_mm3d_matches_dense_random_shapes() {
    for case in 0..12u64 {
        let mut g = Gen::new(5000 + case);
        let p = g.usize_in(1, 2);
        let cube = Cube::new(p);
        let world = p * p * p;
        let dirs = g.dirs();
        let m = p * p * g.usize_in(1, 4);
        let n = p * p * g.usize_in(1, 4);
        let k = p * p * g.usize_in(1, 4);
        let a = g.tensor(&[m, n]);
        let b = g.tensor(&[n, k]);
        let c_ref = a.matmul(&b);
        let a_sh = Layout3D::input(dirs).scatter(&cube, &a);
        let b_sh = Layout3D::weight(dirs).scatter(&cube, &b);
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            mm_nn(ep, &ctx, &a_sh[rank], &b_sh[rank], dirs)
        });
        let c = Layout3D::output(dirs).gather(&cube, &out, m, k);
        assert!(
            c.max_abs_diff(&c_ref) < 1e-3,
            "case {case}: p={p} ({m},{n},{k}) dirs {dirs:?}"
        );
    }
}

#[test]
fn prop_clocks_monotone_and_synchronized() {
    for case in 0..10u64 {
        let mut g = Gen::new(6000 + case);
        let world = g.usize_in(2, 8);
        let elems = g.usize_in(16, 256);
        let rounds = g.usize_in(1, 5);
        let clocks = run_spmd(world, NetModel::flat(1e-6, 1e9, 1e12), move |rank, ep| {
            let group: Vec<usize> = (0..world).collect();
            let mut history = Vec::new();
            let mut rng = Xoshiro256::seed_from_u64(rank as u64);
            for _ in 0..rounds {
                // Unbalanced local work, then a synchronizing collective.
                ep.charge_flops(1e6 * (1.0 + rng.next_f64() * 5.0));
                let t = Tensor::full(&[elems], 1.0);
                let _ = all_reduce(ep, &group, &t);
                history.push(ep.clock);
            }
            history
        });
        // Monotone per rank.
        for (rank, h) in clocks.iter().enumerate() {
            for w in h.windows(2) {
                assert!(w[1] >= w[0], "case {case} rank {rank}: clock went backwards");
            }
        }
        // Ring all-reduce fully synchronizes: after each round all ranks'
        // clocks must agree to within one ring traversal of slack.
        let slack = world as f64 * (1e-6 + (elems * 4) as f64 / 1e9) + 1e-2;
        for round in 0..rounds {
            let vals: Vec<f64> = clocks.iter().map(|h| h[round]).collect();
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = vals.iter().cloned().fold(0.0, f64::max);
            assert!(
                hi - lo <= slack,
                "case {case} round {round}: clocks spread {lo}..{hi} (slack {slack})"
            );
        }
    }
}

#[test]
fn prop_phantom_and_materialized_charge_identical_time() {
    // The central dual-mode invariant: the virtual time of a schedule must
    // not depend on whether data is materialized.
    for case in 0..8u64 {
        let mut g = Gen::new(7000 + case);
        let p = 2;
        let cube = Cube::new(p);
        let dirs = g.dirs();
        let m = 4 * g.usize_in(1, 3);
        let n = 4 * g.usize_in(1, 3);
        let k = 4 * g.usize_in(1, 3);
        let a = g.tensor(&[m, n]);
        let b = g.tensor(&[n, k]);
        let a_sh = Layout3D::input(dirs).scatter(&cube, &a);
        let b_sh = Layout3D::weight(dirs).scatter(&cube, &b);
        let net = NetModel::longhorn_v100();
        let real = run_spmd(8, net.clone(), {
            let (a_sh, b_sh) = (a_sh.clone(), b_sh.clone());
            move |rank, ep| {
                let ctx = Ctx3D::new(Cube::new(p), rank);
                let _ = mm_nn(ep, &ctx, &a_sh[rank], &b_sh[rank], dirs);
                ep.clock
            }
        });
        let phantom = run_spmd(8, net, move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let ap = Tensor::phantom(a_sh[rank].shape());
            let bp = Tensor::phantom(b_sh[rank].shape());
            let _ = mm_nn(ep, &ctx, &ap, &bp, dirs);
            ep.clock
        });
        for (r, (x, y)) in real.iter().zip(phantom.iter()).enumerate() {
            assert!(
                (x - y).abs() < 1e-12,
                "case {case} rank {r}: materialized {x} vs phantom {y}"
            );
        }
    }
}
