//! The headline correctness result: the full Transformer core computes the
//! SAME function under Seq, 1-D, 2-D, 2.5-D, 3-D and hybrid data×tensor
//! parallelism — outputs AND all gradients match the dense reference
//! shard-for-shard, and end-to-end training produces the same loss curve
//! under every parallelism.
//!
//! Since the `ParallelOps` redesign this is ONE generic check: the same
//! loop drives every parallelism through the trait object, and the same
//! `ShardSpec`/`DistTensor` assembly reconstructs globals from shards —
//! no per-dimension gather code. Adding a parallelism means adding one
//! `(kind, edge)` pair to `ALL_ENVS` plus a `new_leaf_*` test naming it
//! (CI runs the `new_leaf` filter before the full suites for fast fail).

use cubic::comm::{Endpoint, NetModel};
use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
use cubic::dist::{DistTensor, ShardSpec, Stage, VecRole};
use cubic::engine::run_training;
use cubic::model::{self, BlockTensors, ParEnv};
use cubic::parallel::{ops_for, ParallelOps};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{HybridInner, Parallelism, PipelineInner};

/// Every parallelism point the crate implements, with its test edge.
const ALL_ENVS: [(Parallelism, usize); 7] = [
    (Parallelism::Seq, 1),
    (Parallelism::OneD, 4),
    (Parallelism::TwoD, 2),
    (Parallelism::ThreeD, 2),
    (Parallelism::TwoFiveD { depth: 2 }, 2),
    (Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2),
    (PIPELINE_ENV.0, PIPELINE_ENV.1),
];

/// The pipeline test point: 2 stages × 1-D p=2, 4 micro-batches (world 4).
const PIPELINE_ENV: (Parallelism, usize) = (
    Parallelism::Pipeline { stages: 2, micro_batches: 4, inner: PipelineInner::OneD },
    2,
);

fn tiny() -> ModelConfig {
    ModelConfig { layers: 2, ..ModelConfig::tiny() }
}

fn randt(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Tensor::randn(shape, 0.5, &mut rng)
}

/// Dense (Seq) forward+backward reference for the core.
fn seq_reference(
    cfg: &ModelConfig,
    x: &Tensor,
    dy: &Tensor,
    seed: u64,
) -> (Tensor, Tensor, Vec<BlockTensors>) {
    let dense = model::init_dense_blocks(cfg, seed);
    let blocks: Vec<BlockTensors> =
        dense.iter().map(|b| b.shard(&ShardSpec::seq())).collect();
    let cfg = cfg.clone();
    let x = x.clone();
    let dy = dy.clone();
    run_spmd(1, NetModel::zero(), move |_, ep| {
        let env = ParEnv::seq();
        let (y, caches) = model::core_fwd(ep, env.ops(), &blocks, &x, &cfg);
        let (dx, grads) = model::core_bwd(ep, env.ops(), &blocks, &caches, &dy, &cfg);
        (y, dx, grads)
    })
    .pop()
    .unwrap()
}

/// Run the core fwd+bwd under one parallelism; per-rank `(y, dx, grads)`.
fn run_par(
    cfg: &ModelConfig,
    par: Parallelism,
    edge: usize,
    x: &Tensor,
    dy: &Tensor,
    seed: u64,
) -> Vec<(Tensor, Tensor, Vec<BlockTensors>)> {
    run_par_net(cfg, par, edge, x, dy, seed, NetModel::zero())
}

/// [`run_par`] under an explicit network model (the overlap sweep pins
/// both schedules with it).
fn run_par_net(
    cfg: &ModelConfig,
    par: Parallelism,
    edge: usize,
    x: &Tensor,
    dy: &Tensor,
    seed: u64,
    net: NetModel,
) -> Vec<(Tensor, Tensor, Vec<BlockTensors>)> {
    let world = par.world_size(edge);
    let cfg2 = cfg.clone();
    let x = x.clone();
    let dy = dy.clone();
    if let Parallelism::Pipeline { stages, micro_batches, inner } = par {
        // Pipelined core: each rank holds its stage's layer slice and the
        // schedule relays the full output/gradient, so y and dx come back
        // global on every rank (with a 1-D inner the unpipelined run's
        // activations are replicated-global too — directly comparable).
        return run_spmd(world, net, move |rank, ep| {
            let ops = cubic::parallel::pipeline::Pipeline::for_kind(
                stages, micro_batches, inner, edge, rank,
            );
            let dense = model::init_dense_blocks(&cfg2, seed);
            let range = ops.layer_range(cfg2.layers);
            let blocks: Vec<BlockTensors> =
                dense[range].iter().map(|b| ops.shard_block(b)).collect();
            let out = cubic::parallel::pipeline::pipeline_core_step(
                ep,
                &ops,
                &blocks,
                &x,
                &cfg2,
                &mut |_ep, _y| dy.clone(),
            );
            ep.join_all();
            (out.y_full, out.dx_full, out.grads)
        });
    }
    run_spmd(world, net, move |rank, ep| {
        let env = ParEnv::new(par, edge, rank);
        let dense = model::init_dense_blocks(&cfg2, seed);
        let blocks = env.shard_blocks(&dense);
        let xl = env.scatter_activation(ep, &x);
        let dyl = env.scatter_activation(ep, &dy);
        let (y, caches) = model::core_fwd(ep, env.ops(), &blocks, &xl, &cfg2);
        let (dx, grads) = model::core_bwd(ep, env.ops(), &blocks, &caches, &dyl, &cfg2);
        (y, dx, grads)
    })
}

const TOL: f32 = 3e-3;

type MatGet = fn(&BlockTensors) -> &Tensor;
type VecGet = fn(&BlockTensors) -> &Option<Tensor>;

/// The generic shard-for-shard parity check for one parallelism point:
/// outputs, input grads, all 4 weight grads and all 8 vector grads per
/// layer reassemble to the dense reference through the spec's own layout
/// algebra.
fn check_matches_seq_reference(par: Parallelism, edge: usize) {
    let cfg = tiny();
    let (h, f) = (cfg.hidden, cfg.ffn);
    let rows = cfg.batch * cfg.seq;
    let x = randt(&[rows, h], 1);
    let dy = randt(&[rows, h], 2);
    let (y_ref, dx_ref, g_ref) = seq_reference(&cfg, &x, &dy, 42);

    let mats: [(&str, Stage, usize, usize, MatGet); 4] = [
        ("w_qkv", Stage::Expand, h, 3 * h, |b| &b.w_qkv),
        ("w_proj", Stage::Reduce, h, h, |b| &b.w_proj),
        ("w_fc1", Stage::Expand, h, f, |b| &b.w_fc1),
        ("w_fc2", Stage::Reduce, f, h, |b| &b.w_fc2),
    ];
    let vecs: [(&str, VecRole, usize, VecGet); 8] = [
        ("ln1_g", VecRole::Norm, h, |b| &b.ln1_g),
        ("ln1_b", VecRole::Norm, h, |b| &b.ln1_b),
        ("b_qkv", VecRole::ExpandBias, 3 * h, |b| &b.b_qkv),
        ("b_proj", VecRole::ReduceBias, h, |b| &b.b_proj),
        ("ln2_g", VecRole::Norm, h, |b| &b.ln2_g),
        ("ln2_b", VecRole::Norm, h, |b| &b.ln2_b),
        ("b_fc1", VecRole::ExpandBias, f, |b| &b.b_fc1),
        ("b_fc2", VecRole::ReduceBias, h, |b| &b.b_fc2),
    ];

    let world = par.world_size(edge);
    let spec0 = ShardSpec::for_parallelism(par, edge, 0);
    let out = run_par(&cfg, par, edge, &x, &dy, 42);

    // Output and input gradient reassemble from every rank's shard.
    let assemble = |pick: fn(&(Tensor, Tensor, Vec<BlockTensors>)) -> &Tensor| {
        let parts: Vec<DistTensor> = out
            .iter()
            .enumerate()
            .map(|(r, o)| {
                DistTensor::from_local(
                    &ShardSpec::for_parallelism(par, edge, r),
                    pick(o).clone(),
                )
            })
            .collect();
        DistTensor::assemble_activation(&parts, rows, h)
    };
    let y = assemble(|o| &o.0);
    let dx = assemble(|o| &o.1);
    assert!(y.max_abs_diff(&y_ref) < TOL, "{par:?} y: {}", y.max_abs_diff(&y_ref));
    assert!(dx.max_abs_diff(&dx_ref) < TOL, "{par:?} dx: {}", dx.max_abs_diff(&dx_ref));
    // Replicated-activation meshes must agree on *every* rank, not
    // just rank 0.
    if !spec0.shards_activation() {
        for (rank, (yr, dxr, _)) in out.iter().enumerate() {
            assert!(yr.max_abs_diff(&y_ref) < TOL, "{par:?} rank {rank} y");
            assert!(dxr.max_abs_diff(&dx_ref) < TOL, "{par:?} rank {rank} dx");
        }
    }

    // Every weight gradient of every layer reassembles to the dense
    // gradient under its stage layout. Pure tensor meshes tile each
    // weight exactly once; hybrid meshes hold one synced copy per
    // data-parallel replica; pipeline stages each own a contiguous layer
    // slice, so layer `l` assembles from its owning stage group alone
    // under the inner spec.
    let pipe_geom = if let Parallelism::Pipeline { stages, inner, .. } = par {
        let iw = inner.as_parallelism().world_size(edge);
        Some((
            cfg.layers / stages,
            iw,
            ShardSpec::for_parallelism(inner.as_parallelism(), edge, 0),
        ))
    } else {
        None
    };
    for l in 0..cfg.layers {
        let (gspec, group, li) = match &pipe_geom {
            Some((per, iw, ispec)) => {
                let k = l / per;
                (ispec, k * iw..(k + 1) * iw, l - k * per)
            }
            None => (&spec0, 0..world, l),
        };
        for (name, stage, wr, wc, get) in mats {
            let parts: Vec<Tensor> =
                group.clone().map(|r| get(&out[r].2[li]).clone()).collect();
            let total: usize = parts.iter().map(|p| p.numel()).sum();
            assert_eq!(
                total,
                wr * wc * gspec.weight_replicas(),
                "{par:?} layer {l} {name} must tile (× replicas)"
            );
            let got = gspec.assemble_weight(stage, &parts, wr, wc);
            let want = get(&g_ref[l]);
            assert!(
                got.max_abs_diff(want) < TOL,
                "{par:?} layer {l} {name}: {}",
                got.max_abs_diff(want)
            );
        }
        // Every vector gradient too, with the ownership pattern the
        // spec prescribes.
        for (name, role, n, get) in vecs {
            let parts: Vec<Option<Tensor>> =
                group.clone().map(|r| get(&out[r].2[li]).clone()).collect();
            for (rank, p) in group.clone().zip(parts.iter()) {
                let owns = ShardSpec::for_parallelism(par, edge, rank).owns_vector(role);
                assert_eq!(p.is_some(), owns, "{par:?} layer {l} {name} rank {rank}");
            }
            let got = gspec.assemble_vector(role, &parts, n);
            let want = get(&g_ref[l]).as_ref().unwrap();
            assert!(
                got.max_abs_diff(want) < TOL,
                "{par:?} layer {l} {name}: {}",
                got.max_abs_diff(want)
            );
        }
    }
    assert_eq!(world, out.len());
}

#[test]
fn every_parallelism_matches_seq_reference() {
    for (par, edge) in ALL_ENVS {
        check_matches_seq_reference(par, edge);
    }
}

// The two newest leaves also get named tests so CI can run
// `cargo test --test model_parity new_leaf` as a fast-fail gate before the
// full dual-thread suites.

#[test]
fn new_leaf_two_five_d_matches_seq_reference() {
    check_matches_seq_reference(Parallelism::TwoFiveD { depth: 2 }, 2);
}

#[test]
fn new_leaf_hybrid_matches_seq_reference() {
    check_matches_seq_reference(
        Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD },
        2,
    );
}

#[test]
fn new_leaf_hybrid_two_d_inner_matches_seq_reference() {
    // The wrapper must compose with a sharding inner mesh too: 2 replicas
    // around a 2×2 SUMMA grid (world 8).
    check_matches_seq_reference(
        Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD },
        2,
    );
}

#[test]
fn new_leaf_pipeline_matches_seq_reference() {
    check_matches_seq_reference(PIPELINE_ENV.0, PIPELINE_ENV.1);
}

#[test]
fn pipeline_is_bitwise_identical_to_unpipelined_inner() {
    // The tentpole's headline numerics claim: Pipeline(s=2, m=4) around a
    // 1-D p=2 inner produces BITWISE-identical output, input grads, and
    // per-layer weight grads to the unpipelined 1-D run at the same global
    // batch — micro-batching only reorders row-disjoint work, and the
    // wgrad flush contracts over concatenated rows in full-batch order.
    // Both CUBIC_OVERLAP legs are pinned by setting overlap directly.
    let cfg = tiny();
    let rows = cfg.batch * cfg.seq;
    let x = randt(&[rows, cfg.hidden], 11);
    let dy = randt(&[rows, cfg.hidden], 12);
    let (par, edge) = PIPELINE_ENV;
    let (stages, per) = (2usize, cfg.layers / 2);
    for overlap in [false, true] {
        let mut net = NetModel::zero();
        net.overlap = overlap;
        let piped = run_par_net(&cfg, par, edge, &x, &dy, 42, net.clone());
        let flat = run_par_net(&cfg, Parallelism::OneD, 2, &x, &dy, 42, net);
        assert_eq!(piped.len(), stages * 2);
        for (rank, (y, dx, grads)) in piped.iter().enumerate() {
            let inner_rank = rank % 2;
            let stage = rank / 2;
            let (fy, fdx, fgrads) = &flat[inner_rank];
            // 1-D activations are replicated-global, so the pipeline's
            // relayed y_full/dx_full must match them bit for bit.
            assert_eq!(y.data(), fy.data(), "overlap={overlap} rank {rank} y");
            assert_eq!(dx.data(), fdx.data(), "overlap={overlap} rank {rank} dx");
            assert_eq!(grads.len(), per, "overlap={overlap} rank {rank} grads len");
            for (li, g) in grads.iter().enumerate() {
                let fg = &fgrads[stage * per + li];
                for (name, get) in [
                    ("w_qkv", (|b| &b.w_qkv) as MatGet),
                    ("w_proj", |b| &b.w_proj),
                    ("w_fc1", |b| &b.w_fc1),
                    ("w_fc2", |b| &b.w_fc2),
                ] {
                    assert_eq!(
                        get(g).data(),
                        get(fg).data(),
                        "overlap={overlap} rank {rank} local layer {li} {name}"
                    );
                }
                for (name, get) in [
                    ("ln1_g", (|b| &b.ln1_g) as VecGet),
                    ("ln1_b", |b| &b.ln1_b),
                    ("b_qkv", |b| &b.b_qkv),
                    ("b_proj", |b| &b.b_proj),
                    ("ln2_g", |b| &b.ln2_g),
                    ("ln2_b", |b| &b.ln2_b),
                    ("b_fc1", |b| &b.b_fc1),
                    ("b_fc2", |b| &b.b_fc2),
                ] {
                    match (get(g), get(fg)) {
                        (Some(a), Some(b)) => assert_eq!(
                            a.data(),
                            b.data(),
                            "overlap={overlap} rank {rank} local layer {li} {name}"
                        ),
                        (None, None) => {}
                        _ => panic!(
                            "overlap={overlap} rank {rank} local layer {li} {name}: \
                             ownership differs from unpipelined inner"
                        ),
                    }
                }
            }
        }
    }
}

#[test]
fn matmul_forms_compose_and_match_dense() {
    // Pin the trait-level matmul primitives the generic block does not
    // exercise directly (it goes through linear_fwd/bwd): two chained
    // matmul_nn calls (Expand then Reduce) must return the activation to
    // the entry layout, and the nt/tn forms must produce the dense input
    // and weight gradients under each stage's layout. Every intermediate
    // is consumed by a further trait op, so the per-stage output layouts
    // (1-D column shards, 2.5-D depth slabs, 3-D swapped directions) are
    // verified by composition rather than bespoke gathers.
    let (rows, h, f) = (8usize, 16usize, 32usize);
    let x = randt(&[rows, h], 21);
    let w1 = randt(&[h, f], 22);
    let w2 = randt(&[f, h], 23);
    let dy = randt(&[rows, h], 24);
    let hmid_ref = x.matmul(&w1);
    let y_ref = hmid_ref.matmul(&w2);
    let dh_ref = dy.matmul_nt(&w2);
    let dx_ref = dh_ref.matmul_nt(&w1);
    let dw2_ref = hmid_ref.matmul_tn(&dy);
    let dw1_ref = x.matmul_tn(&dh_ref);

    for (par, edge) in ALL_ENVS {
        let world = par.world_size(edge);
        let (x2, w1c, w2c, dy2) = (x.clone(), w1.clone(), w2.clone(), dy.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ops: Box<dyn ParallelOps> = ops_for(par, edge, rank);
            let spec = ops.spec().clone();
            let xl = ops.scatter_activation(ep, &x2);
            let dyl = ops.scatter_activation(ep, &dy2);
            let w1s = spec.shard_weight(Stage::Expand, &w1c);
            let w2s = spec.shard_weight(Stage::Reduce, &w2c);
            // Forward: Expand then Reduce lands back in the entry layout.
            let hmid = ops.matmul_nn(ep, &xl, &w1s, Stage::Expand);
            let y = ops.matmul_nn(ep, &hmid, &w2s, Stage::Reduce);
            // Input grads: Reduce-nt then Expand-nt retraces the layouts.
            let dh = ops.matmul_nt(ep, &dyl, &w2s, Stage::Reduce);
            let dx = ops.matmul_nt(ep, &dh, &w1s, Stage::Expand);
            // Weight grads in each stage's own weight layout.
            let dw2 = ops.matmul_tn(ep, &hmid, &dyl, Stage::Reduce);
            let dw1 = ops.matmul_tn(ep, &xl, &dh, Stage::Expand);
            (y, dx, dw1, dw2)
        });
        let spec0 = ShardSpec::for_parallelism(par, edge, 0);
        let acts = |pick: fn(&(Tensor, Tensor, Tensor, Tensor)) -> &Tensor| {
            let parts: Vec<Tensor> = out.iter().map(|o| pick(o).clone()).collect();
            spec0.assemble_activation(&parts, rows, h)
        };
        let y = acts(|o| &o.0);
        let dx = acts(|o| &o.1);
        assert!(y.max_abs_diff(&y_ref) < TOL, "{par:?} y: {}", y.max_abs_diff(&y_ref));
        assert!(dx.max_abs_diff(&dx_ref) < TOL, "{par:?} dx: {}", dx.max_abs_diff(&dx_ref));
        let dw1_parts: Vec<Tensor> = out.iter().map(|o| o.2.clone()).collect();
        let dw1 = spec0.assemble_weight(Stage::Expand, &dw1_parts, h, f);
        assert!(dw1.max_abs_diff(&dw1_ref) < TOL, "{par:?} dw1: {}", dw1.max_abs_diff(&dw1_ref));
        let dw2_parts: Vec<Tensor> = out.iter().map(|o| o.3.clone()).collect();
        let dw2 = spec0.assemble_weight(Stage::Reduce, &dw2_parts, f, h);
        assert!(dw2.max_abs_diff(&dw2_ref) < TOL, "{par:?} dw2: {}", dw2.max_abs_diff(&dw2_ref));
    }
}

#[test]
fn trait_object_dispatch_smoke() {
    // Drive each implementation strictly through `Box<dyn ParallelOps>`
    // (the dispatch ParEnv uses): provided layout methods and a
    // dynamically-dispatched vec_op must agree with the dense result.
    let (rows, cols) = (8usize, 16usize);
    let global = randt(&[rows, cols], 7);
    let v = randt(&[cols], 8);
    let want = global.add_row_vector(&v);
    for (par, edge) in ALL_ENVS {
        let world = par.world_size(edge);
        let g2 = global.clone();
        let v2 = v.clone();
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ops: Box<dyn ParallelOps> = ops_for(par, edge, rank);
            assert_eq!(ops.kind(), par);
            assert_eq!(ops.spec().world(), world);
            assert_eq!(ops.spec().rank, rank);
            let xl = ops.scatter_activation(ep, &g2);
            assert_eq!(
                xl.shape(),
                &[
                    ops.activation_shape(rows, cols).0,
                    ops.activation_shape(rows, cols).1
                ]
            );
            let chunk = ops.spec().shard_vector(VecRole::Norm, &v2);
            let y = ops.vec_op(ep, &xl, chunk.as_ref(), false);
            ops.gather_activation(ep, &y, rows, cols)
        });
        for (rank, got) in out.iter().enumerate() {
            assert!(
                got.max_abs_diff(&want) < 1e-5,
                "{par:?} rank {rank}: dyn vec_op mismatch"
            );
        }
    }
}

#[test]
fn activation_scatter_gather_steady_state_recycles() {
    // The pooled boundary path (ROADMAP pool follow-on): on a sharding
    // mesh, scatter_activation cuts the window into a pooled buffer and
    // gather_activation assembles into one — after warmup each call pair
    // takes exactly two pooled buffers and allocates nothing.
    let iters = 5u64;
    let out = run_spmd(4, NetModel::zero(), move |rank, ep| {
        let env = ParEnv::new(Parallelism::TwoD, 2, rank);
        let global = Tensor::full(&[8, 16], 2.0);
        let run_one = |ep: &mut Endpoint| {
            let xl = env.scatter_activation(ep, &global);
            let back = env.gather_activation(ep, &xl, 8, 16);
            assert_eq!(back.data()[0], 2.0);
            drop(back);
            drop(xl);
            ep.barrier_wait();
        };
        run_one(ep); // warmup allocates the shard + assembly buffers once
        let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
        for _ in 0..iters {
            run_one(ep);
        }
        (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
    });
    for (rank, (hits, misses)) in out.iter().enumerate() {
        assert_eq!(*misses, 0, "rank {rank}: boundary path must not allocate after warmup");
        assert_eq!(*hits, 2 * iters, "rank {rank}: one pooled scatter + one pooled gather");
    }
}

#[test]
fn overlap_vs_serialized_is_bitwise_identical_for_every_kind() {
    // The tentpole's bit-exactness-by-construction claim, pinned: deferred
    // collectives move data at issue time and only the *clock* is
    // re-timed, so the overlapped and serialized schedules must produce
    // bitwise-identical outputs, input grads, and every weight/vector grad
    // on every rank of every mesh kind. `overlap` is set directly on the
    // NetModel so this holds under either CUBIC_OVERLAP CI leg.
    let cfg = tiny();
    let rows = cfg.batch * cfg.seq;
    let x = randt(&[rows, cfg.hidden], 5);
    let dy = randt(&[rows, cfg.hidden], 6);
    let net_with = |overlap: bool| {
        let mut net = NetModel::zero();
        net.overlap = overlap;
        net
    };
    let mats: [(&str, MatGet); 4] = [
        ("w_qkv", |b| &b.w_qkv),
        ("w_proj", |b| &b.w_proj),
        ("w_fc1", |b| &b.w_fc1),
        ("w_fc2", |b| &b.w_fc2),
    ];
    let vecs: [(&str, VecGet); 8] = [
        ("ln1_g", |b| &b.ln1_g),
        ("ln1_b", |b| &b.ln1_b),
        ("b_qkv", |b| &b.b_qkv),
        ("b_proj", |b| &b.b_proj),
        ("ln2_g", |b| &b.ln2_g),
        ("ln2_b", |b| &b.ln2_b),
        ("b_fc1", |b| &b.b_fc1),
        ("b_fc2", |b| &b.b_fc2),
    ];
    for (par, edge) in ALL_ENVS {
        let serial = run_par_net(&cfg, par, edge, &x, &dy, 42, net_with(false));
        let overlapped = run_par_net(&cfg, par, edge, &x, &dy, 42, net_with(true));
        for (rank, (s, o)) in serial.iter().zip(&overlapped).enumerate() {
            assert_eq!(s.0.data(), o.0.data(), "{par:?} rank {rank} y");
            assert_eq!(s.1.data(), o.1.data(), "{par:?} rank {rank} dx");
            for (l, (gs, go)) in s.2.iter().zip(&o.2).enumerate() {
                for (name, get) in mats {
                    assert_eq!(
                        get(gs).data(),
                        get(go).data(),
                        "{par:?} rank {rank} layer {l} {name}"
                    );
                }
                for (name, get) in vecs {
                    match (get(gs), get(go)) {
                        (Some(a), Some(b)) => assert_eq!(
                            a.data(),
                            b.data(),
                            "{par:?} rank {rank} layer {l} {name}"
                        ),
                        (None, None) => {}
                        _ => panic!("{par:?} rank {rank} layer {l} {name}: ownership differs"),
                    }
                }
            }
        }
    }
}

#[test]
fn in_flight_collective_buffers_steady_state_recycle() {
    // Pending collectives own their pooled buffers while deferred; after a
    // one-iteration warmup, a loop keeping two all-reduces in flight must
    // recycle every buffer (0 allocations, exactly 2 pooled takes per
    // aligned all-reduce).
    let iters = 5u64;
    let mut net = NetModel::zero();
    net.overlap = true; // in-flight handles regardless of CUBIC_OVERLAP
    let out = run_spmd(2, net, move |_rank, ep| {
        let t = Tensor::full(&[64], 1.0);
        let run_one = |ep: &mut Endpoint| {
            let p1 = ep.iall_reduce(&[0, 1], &t);
            let p2 = ep.iall_reduce(&[0, 1], &t);
            assert!(p1.is_deferred() && p2.is_deferred());
            assert_eq!(ep.pending_colls(), 2);
            let a = p1.wait(ep);
            let b = p2.wait(ep);
            assert_eq!(a.data()[0], 2.0);
            assert_eq!(b.data()[0], 2.0);
            drop(a); // release the pooled buffers before the next round
            drop(b);
            ep.barrier_wait();
        };
        run_one(ep); // warmup allocates the round's buffers once
        let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
        for _ in 0..iters {
            run_one(ep);
        }
        (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
    });
    for (rank, (hits, misses)) in out.iter().enumerate() {
        assert_eq!(*misses, 0, "rank {rank}: in-flight path must not allocate after warmup");
        assert_eq!(*hits, 2 * 2 * iters, "rank {rank}: 2 pooled takes per all-reduce");
    }
}

#[test]
fn training_loss_curves_identical_across_parallelisms() {
    // The whole-system invariant: training the same model+data under every
    // parallelism yields the same loss trajectory (to f32 noise).
    // Two layers so the pipeline point (2 stages) divides the stack.
    let model = ModelConfig { layers: 2, ..ModelConfig::tiny() };
    let train = TrainConfig { steps: 6, lr: 1e-3, warmup: 2, ..Default::default() };
    let mk = |par, edge| CubicConfig {
        model: model.clone(),
        train: train.clone(),
        parallelism: par,
        edge,
        ..CubicConfig::default()
    };
    let seq = run_training(&mk(Parallelism::Seq, 1), NetModel::zero()).unwrap();
    for (par, edge) in &ALL_ENVS[1..] {
        let rep = run_training(&mk(*par, *edge), NetModel::zero()).unwrap();
        assert_eq!(rep.losses.len(), seq.losses.len());
        for (s, (a, b)) in rep.losses.iter().zip(seq.losses.iter()).enumerate() {
            assert!(
                (a - b).abs() < 2e-2 * (1.0 + b.abs()),
                "{par:?} step {s}: {a} vs seq {b}"
            );
        }
    }
    // And the loss does go down.
    assert!(seq.losses.last().unwrap() < &seq.losses[0]);
}

#[test]
fn zero_training_is_bitwise_identical_to_replicated_hybrid() {
    // The ZeRO headline pin: reduce-scattered gradients + 1/r-partitioned
    // Adam moments + post-step weight all-gather produce BITWISE the same
    // loss curve as the replicated all-reduce path, on both hybrid parity
    // points, under both overlap schedules. The construction: `all_reduce`
    // IS reduce-scatter + all-gather on the same `flat_chunks` boundaries
    // (same ring, same fold order), so the owned grad chunk equals the
    // matching slice of the all-reduced gradient bit for bit, and Adam is
    // elementwise — the partitioned update writes exactly the bits the
    // replicated update would, and the gather replicates them back.
    let model = ModelConfig { layers: 2, ..ModelConfig::tiny() };
    let train = TrainConfig { steps: 5, lr: 1e-3, warmup: 2, ..Default::default() };
    let mk = |par, edge, zero_stage| CubicConfig {
        model: model.clone(),
        train: train.clone(),
        parallelism: par,
        edge,
        zero_stage,
        ..CubicConfig::default()
    };
    for (par, edge) in [
        (Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2),
        (Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 2),
    ] {
        for overlap in [false, true] {
            let mut net = NetModel::zero();
            net.overlap = overlap;
            let off = run_training(&mk(par, edge, 0), net.clone()).unwrap();
            // Stages 1 and 2 share the execution path (they differ only in
            // the cost model's grad-residency accounting) — pin both.
            for stage in [1usize, 2] {
                let on = run_training(&mk(par, edge, stage), net.clone()).unwrap();
                assert_eq!(
                    off.losses, on.losses,
                    "{par:?} overlap={overlap} zero_stage={stage}"
                );
            }
            assert!(off.losses.last().unwrap() < &off.losses[0], "{par:?} learns");
        }
    }
}

#[test]
fn zero_with_single_replica_is_a_bitwise_noop() {
    // r = 1 degenerate: reduce_scatter hands back the lone flat chunk, the
    // partition spans every element, and the post-step all-gather is a
    // local copy — so ZeRO-on must be bit-identical to ZeRO-off even
    // though the group has nobody to communicate with.
    let model = ModelConfig { layers: 2, ..ModelConfig::tiny() };
    let train = TrainConfig { steps: 4, lr: 1e-3, warmup: 1, ..Default::default() };
    let par = Parallelism::Hybrid { replicas: 1, inner: HybridInner::OneD };
    let mk = |zero_stage| CubicConfig {
        model: model.clone(),
        train: train.clone(),
        parallelism: par,
        edge: 2,
        zero_stage,
        ..CubicConfig::default()
    };
    let off = run_training(&mk(0), NetModel::zero()).unwrap();
    let on = run_training(&mk(1), NetModel::zero()).unwrap();
    assert_eq!(off.losses, on.losses);
}
