//! The headline correctness result: the full Transformer core computes the
//! SAME function under Seq, 1-D, 2-D and 3-D parallelism — outputs AND all
//! gradients match the dense reference shard-for-shard, and end-to-end
//! training produces the same loss curve under every parallelism.

use cubic::comm::NetModel;
use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
use cubic::dist::{DiagVec3D, Dirs, Layout2D, Layout3D};
use cubic::engine::run_training;
use cubic::model::{self, BlockTensors, ParEnv};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{Cube, Mesh, Parallelism};

fn tiny() -> ModelConfig {
    ModelConfig { layers: 2, ..ModelConfig::tiny() }
}

fn randt(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Tensor::randn(shape, 0.5, &mut rng)
}

/// Dense (Seq) forward+backward reference for the core.
fn seq_reference(
    cfg: &ModelConfig,
    x: &Tensor,
    dy: &Tensor,
    seed: u64,
) -> (Tensor, Tensor, Vec<BlockTensors>) {
    let dense = model::init_dense_blocks(cfg, seed);
    let blocks: Vec<BlockTensors> = dense.iter().map(|b| b.to_seq()).collect();
    let cfg = cfg.clone();
    let x = x.clone();
    let dy = dy.clone();
    run_spmd(1, NetModel::zero(), move |_, ep| {
        let env = ParEnv::Seq;
        let (y, caches) = model::core_fwd(ep, &env, &blocks, &x, &cfg);
        let (dx, grads) = model::core_bwd(ep, &env, &blocks, &caches, &dy, &cfg);
        (y, dx, grads)
    })
    .pop()
    .unwrap()
}

fn run_par(
    cfg: &ModelConfig,
    par: Parallelism,
    edge: usize,
    x: &Tensor,
    dy: &Tensor,
    seed: u64,
) -> Vec<(Tensor, Tensor, Vec<BlockTensors>)> {
    let world = par.world_size(edge);
    let cfg2 = cfg.clone();
    let x = x.clone();
    let dy = dy.clone();
    run_spmd(world, NetModel::zero(), move |rank, ep| {
        let env = ParEnv::new(par, edge, rank);
        let dense = model::init_dense_blocks(&cfg2, seed);
        let blocks = env.shard_blocks(&dense, rank);
        let xl = env.scatter_activation(&x, rank);
        let dyl = env.scatter_activation(&dy, rank);
        let (y, caches) = model::core_fwd(ep, &env, &blocks, &xl, &cfg2);
        let (dx, grads) = model::core_bwd(ep, &env, &blocks, &caches, &dyl, &cfg2);
        (y, dx, grads)
    })
}

const TOL: f32 = 3e-3;

#[test]
fn oned_core_matches_seq_reference() {
    let cfg = tiny();
    let rows = cfg.batch * cfg.seq;
    let x = randt(&[rows, cfg.hidden], 1);
    let dy = randt(&[rows, cfg.hidden], 2);
    let (y_ref, dx_ref, g_ref) = seq_reference(&cfg, &x, &dy, 42);
    let out = run_par(&cfg, Parallelism::OneD, 4, &x, &dy, 42);
    // Activations replicated: every rank must match the reference.
    for (rank, (y, dx, grads)) in out.iter().enumerate() {
        assert!(y.max_abs_diff(&y_ref) < TOL, "rank {rank} y");
        assert!(dx.max_abs_diff(&dx_ref) < TOL, "rank {rank} dx");
        // Replicated vector grads (ln, b_proj, b_fc2) must match directly.
        for l in 0..cfg.layers {
            let g = &grads[l];
            let r = &g_ref[l];
            assert!(
                g.ln1_g.as_ref().unwrap().max_abs_diff(r.ln1_g.as_ref().unwrap()) < TOL,
                "rank {rank} layer {l} ln1_g"
            );
            assert!(
                g.b_proj.as_ref().unwrap().max_abs_diff(r.b_proj.as_ref().unwrap()) < TOL,
                "rank {rank} layer {l} b_proj"
            );
        }
    }
    // Sharded weight grads reassemble to the dense grads.
    for l in 0..cfg.layers {
        let wq: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_qkv.clone()).collect();
        let wq = cubic::dist::Layout1D::ColShard.gather(&wq);
        assert!(wq.max_abs_diff(&g_ref[l].w_qkv) < TOL, "layer {l} w_qkv");
        let w2: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_fc2.clone()).collect();
        let w2 = cubic::dist::Layout1D::RowShard.gather(&w2);
        assert!(w2.max_abs_diff(&g_ref[l].w_fc2) < TOL, "layer {l} w_fc2");
    }
}

#[test]
fn twod_core_matches_seq_reference() {
    let cfg = tiny();
    let rows = cfg.batch * cfg.seq;
    let mesh = Mesh::new(2);
    let x = randt(&[rows, cfg.hidden], 3);
    let dy = randt(&[rows, cfg.hidden], 4);
    let (y_ref, dx_ref, g_ref) = seq_reference(&cfg, &x, &dy, 43);
    let out = run_par(&cfg, Parallelism::TwoD, 2, &x, &dy, 43);
    let y_shards: Vec<Tensor> = out.iter().map(|(y, _, _)| y.clone()).collect();
    let y = Layout2D::gather(&mesh, &y_shards, rows, cfg.hidden);
    assert!(y.max_abs_diff(&y_ref) < TOL, "y");
    let dx_shards: Vec<Tensor> = out.iter().map(|(_, dx, _)| dx.clone()).collect();
    let dx = Layout2D::gather(&mesh, &dx_shards, rows, cfg.hidden);
    assert!(dx.max_abs_diff(&dx_ref) < TOL, "dx");
    for l in 0..cfg.layers {
        let wq: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_qkv.clone()).collect();
        let wq = Layout2D::gather(&mesh, &wq, cfg.hidden, 3 * cfg.hidden);
        assert!(wq.max_abs_diff(&g_ref[l].w_qkv) < TOL, "layer {l} w_qkv");
        // Bias grads live on mesh row 0 as column chunks.
        let q = 2;
        let bq: Vec<Tensor> = (0..q)
            .map(|c| out[c].2[l].b_qkv.as_ref().unwrap().reshape(&[1, 3 * cfg.hidden / q]))
            .collect();
        let bq = Tensor::concat_cols(&bq);
        assert!(
            bq.max_abs_diff(&g_ref[l].b_qkv.as_ref().unwrap().reshape(&[1, 3 * cfg.hidden]))
                < TOL,
            "layer {l} b_qkv"
        );
    }
}

#[test]
fn threed_core_matches_seq_reference() {
    let cfg = tiny();
    let rows = cfg.batch * cfg.seq;
    let cube = Cube::new(2);
    let d0 = Dirs::canonical();
    let x = randt(&[rows, cfg.hidden], 5);
    let dy = randt(&[rows, cfg.hidden], 6);
    let (y_ref, dx_ref, g_ref) = seq_reference(&cfg, &x, &dy, 44);
    let out = run_par(&cfg, Parallelism::ThreeD, 2, &x, &dy, 44);
    let y_shards: Vec<Tensor> = out.iter().map(|(y, _, _)| y.clone()).collect();
    let y = Layout3D::input(d0).gather(&cube, &y_shards, rows, cfg.hidden);
    assert!(y.max_abs_diff(&y_ref) < TOL, "y: {}", y.max_abs_diff(&y_ref));
    let dx_shards: Vec<Tensor> = out.iter().map(|(_, dx, _)| dx.clone()).collect();
    let dx = Layout3D::input(d0).gather(&cube, &dx_shards, rows, cfg.hidden);
    assert!(dx.max_abs_diff(&dx_ref) < TOL, "dx: {}", dx.max_abs_diff(&dx_ref));
    let d1 = d0.swapped();
    for l in 0..cfg.layers {
        // Weight grads reassemble under their layer's layouts.
        let wq: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_qkv.clone()).collect();
        let wq = Layout3D::weight(d0).gather(&cube, &wq, cfg.hidden, 3 * cfg.hidden);
        assert!(wq.max_abs_diff(&g_ref[l].w_qkv) < TOL, "layer {l} w_qkv");
        let wp: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_proj.clone()).collect();
        let wp = Layout3D::weight(d1).gather(&cube, &wp, cfg.hidden, cfg.hidden);
        assert!(wp.max_abs_diff(&g_ref[l].w_proj) < TOL, "layer {l} w_proj");
        let w1: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_fc1.clone()).collect();
        let w1 = Layout3D::weight(d0).gather(&cube, &w1, cfg.hidden, cfg.ffn);
        assert!(w1.max_abs_diff(&g_ref[l].w_fc1) < TOL, "layer {l} w_fc1");
        let w2: Vec<Tensor> = out.iter().map(|(_, _, g)| g[l].w_fc2.clone()).collect();
        let w2 = Layout3D::weight(d1).gather(&cube, &w2, cfg.ffn, cfg.hidden);
        assert!(w2.max_abs_diff(&g_ref[l].w_fc2) < TOL, "layer {l} w_fc2");
        // Vector grads reassemble from the diagonals.
        let bq: Vec<Option<Tensor>> = out.iter().map(|(_, _, g)| g[l].b_qkv.clone()).collect();
        let bq = DiagVec3D::for_dirs(d1).gather(&cube, &bq, 3 * cfg.hidden);
        assert!(
            bq.max_abs_diff(g_ref[l].b_qkv.as_ref().unwrap()) < TOL,
            "layer {l} b_qkv"
        );
        let g1: Vec<Option<Tensor>> = out.iter().map(|(_, _, g)| g[l].ln1_g.clone()).collect();
        let g1 = DiagVec3D::for_dirs(d0).gather(&cube, &g1, cfg.hidden);
        assert!(
            g1.max_abs_diff(g_ref[l].ln1_g.as_ref().unwrap()) < TOL,
            "layer {l} ln1_g"
        );
    }
}

#[test]
fn training_loss_curves_identical_across_parallelisms() {
    // The whole-system invariant: training the same model+data under every
    // parallelism yields the same loss trajectory (to f32 noise).
    let model = ModelConfig { layers: 1, ..ModelConfig::tiny() };
    let train = TrainConfig { steps: 6, lr: 1e-3, warmup: 2, ..Default::default() };
    let mk = |par, edge| CubicConfig {
        model: model.clone(),
        train: train.clone(),
        parallelism: par,
        edge,
        artifacts_dir: String::new(),
    };
    let seq = run_training(&mk(Parallelism::Seq, 1), NetModel::zero()).unwrap();
    let d1 = run_training(&mk(Parallelism::OneD, 4), NetModel::zero()).unwrap();
    let d2 = run_training(&mk(Parallelism::TwoD, 2), NetModel::zero()).unwrap();
    let d3 = run_training(&mk(Parallelism::ThreeD, 2), NetModel::zero()).unwrap();
    for (name, rep) in [("1d", &d1), ("2d", &d2), ("3d", &d3)] {
        assert_eq!(rep.losses.len(), seq.losses.len());
        for (s, (a, b)) in rep.losses.iter().zip(seq.losses.iter()).enumerate() {
            assert!(
                (a - b).abs() < 2e-2 * (1.0 + b.abs()),
                "{name} step {s}: {a} vs seq {b}"
            );
        }
    }
    // And the loss does go down.
    assert!(seq.losses.last().unwrap() < &seq.losses[0]);
}
